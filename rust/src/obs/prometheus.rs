//! Prometheus text exposition format 0.0.4.
//!
//! Renders a [`Registry`](super::registry::Registry) as the plain-text
//! scrape format: one `# HELP` and `# TYPE` line per metric family,
//! followed by the samples.  Histograms expand to the conventional
//! `_bucket{le="..."}` cumulative series plus `_sum` and `_count`.
//!
//! Serve it with `Content-Type: text/plain; version=0.0.4`
//! ([`CONTENT_TYPE`]) — `net::routes` does, on `GET /metrics`.
//!
//! The output is deterministic: families render in name order (the
//! registry map is a `BTreeMap`) and bucket edges are fixed powers of
//! two, so two scrapes differ only in the sample values.

use super::registry::{Entry, Metric, Registry};

/// The `Content-Type` of text exposition format 0.0.4.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Escape a HELP line: `\` and newline.
fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Escape a label value: `\`, `"`, and newline (exposition-format rules).
pub fn escape_label_value(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render constant labels as `{k="v",...}`, empty string when none.
fn label_block(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

/// `le` label joined onto existing constant labels.
fn le_block(labels: &[(String, String)], le: &str) -> String {
    let mut inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    inner.push(format!("le=\"{le}\""));
    format!("{{{}}}", inner.join(","))
}

/// Format a float sample value the way Prometheus expects (shortest
/// round-trip; integral values without an exponent).
fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn render_entry(out: &mut String, name: &str, entry: &Entry) {
    let kind = match entry.metric {
        Metric::Counter(_) => "counter",
        Metric::Gauge(_) => "gauge",
        Metric::Histogram(_) => "histogram",
    };
    out.push_str(&format!("# HELP {name} {}\n", escape_help(&entry.help)));
    out.push_str(&format!("# TYPE {name} {kind}\n"));
    match &entry.metric {
        Metric::Counter(c) => {
            out.push_str(&format!("{name}{} {}\n", label_block(&entry.labels), c.get()));
        }
        Metric::Gauge(g) => {
            out.push_str(&format!("{name}{} {}\n", label_block(&entry.labels), g.get()));
        }
        Metric::Histogram(h) => {
            let snap = h.snapshot();
            let mut cum = 0u64;
            for (i, &edge) in snap.edges.iter().enumerate() {
                cum += snap.counts[i];
                out.push_str(&format!(
                    "{name}_bucket{} {cum}\n",
                    le_block(&entry.labels, &fmt_value(edge)),
                ));
            }
            cum += snap.counts.last().copied().unwrap_or(0);
            out.push_str(&format!(
                "{name}_bucket{} {cum}\n",
                le_block(&entry.labels, "+Inf"),
            ));
            out.push_str(&format!(
                "{name}_sum{} {}\n",
                label_block(&entry.labels),
                fmt_value(snap.sum),
            ));
            out.push_str(&format!("{name}_count{} {}\n", label_block(&entry.labels), cum));
        }
    }
}

/// Render every metric in `registry`, name-ordered.
pub fn render(registry: &Registry) -> String {
    let mut out = String::new();
    for (name, entry) in registry.entries() {
        render_entry(&mut out, &name, &entry);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_with_help_and_type_lines() {
        let reg = Registry::new();
        let c = reg.counter("hpgnn_test_requests_total", "Requests accepted.");
        let g = reg.gauge("hpgnn_test_depth", "Queue depth.");
        c.add(3);
        g.add(2);
        let text = render(&reg);
        assert!(text.contains("# HELP hpgnn_test_requests_total Requests accepted.\n"));
        assert!(text.contains("# TYPE hpgnn_test_requests_total counter\n"));
        assert!(text.contains("\nhpgnn_test_requests_total 3\n"));
        assert!(text.contains("# TYPE hpgnn_test_depth gauge\n"));
        assert!(text.contains("\nhpgnn_test_depth 2\n"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "unparsable sample: {line}");
            assert!(parts.next().is_some(), "no metric name: {line}");
        }
    }

    #[test]
    fn histograms_expand_to_cumulative_buckets_sum_and_count() {
        let reg = Registry::new();
        let h = reg.histogram("hpgnn_test_latency_seconds", "Latency.", -2, 1);
        h.observe(0.2); // -> le=0.25
        h.observe(0.2); // -> le=0.25
        h.observe(0.6); // -> le=1
        h.observe(9.0); // -> overflow
        let text = render(&reg);
        assert!(text.contains("# TYPE hpgnn_test_latency_seconds histogram\n"));
        assert!(text.contains("hpgnn_test_latency_seconds_bucket{le=\"0.25\"} 2\n"));
        assert!(text.contains("hpgnn_test_latency_seconds_bucket{le=\"0.5\"} 2\n"));
        assert!(text.contains("hpgnn_test_latency_seconds_bucket{le=\"1\"} 3\n"));
        assert!(text.contains("hpgnn_test_latency_seconds_bucket{le=\"2\"} 3\n"));
        assert!(text.contains("hpgnn_test_latency_seconds_bucket{le=\"+Inf\"} 4\n"));
        assert!(text.contains("hpgnn_test_latency_seconds_count 4\n"));
        assert!(text.contains("hpgnn_test_latency_seconds_sum 10"));
    }

    #[test]
    fn label_values_escape_backslash_quote_and_newline() {
        assert_eq!(escape_label_value(r"a\b"), r"a\\b");
        assert_eq!(escape_label_value("say \"hi\""), "say \\\"hi\\\"");
        assert_eq!(escape_label_value("two\nlines"), "two\\nlines");
        let reg = Registry::new();
        reg.counter_with_labels(
            "hpgnn_test_labeled_total",
            "Labeled.",
            vec![("path".to_string(), "C:\\x \"q\"\nend".to_string())],
        );
        let text = render(&reg);
        assert!(
            text.contains("hpgnn_test_labeled_total{path=\"C:\\\\x \\\"q\\\"\\nend\"} 0\n"),
            "{text}"
        );
    }

    #[test]
    fn counters_are_monotone_across_scrapes() {
        let reg = Registry::new();
        let c = reg.counter("hpgnn_test_scrapes_total", "Scrape counter.");
        let value_of = |text: &str| -> u64 {
            text.lines()
                .find(|l| l.starts_with("hpgnn_test_scrapes_total "))
                .and_then(|l| l.rsplit(' ').next())
                .and_then(|v| v.parse().ok())
                .expect("sample line")
        };
        let mut last = value_of(&render(&reg));
        for i in 0..5 {
            c.add(i);
            let now = value_of(&render(&reg));
            assert!(now >= last, "counter went backwards: {last} -> {now}");
            last = now;
        }
        assert_eq!(last, 10);
    }
}
