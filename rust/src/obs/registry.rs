//! Typed metrics: monotonic counters, gauges, and fixed log-scale-bucket
//! histograms, grouped under a [`Registry`] for Prometheus exposition.
//!
//! Design constraints (the determinism + serving-robustness contracts):
//!
//! * **Bounded memory** — a [`Histogram`] is a fixed array of power-of-two
//!   buckets sized at construction; recording never allocates, so metrics
//!   can sit on the serving hot path.
//! * **Deterministic bucket edges** — edges are exactly `2^i` computed
//!   with [`f64::powi`], identical on every platform; two machines
//!   observing the same samples report the same buckets.
//! * **Lock-free recording** — counters, gauges, and histogram buckets are
//!   atomics; the registry's map lock is taken only at registration and
//!   render time, never while recording.
//!
//! Recorded values are *observed, never branched on*: nothing in the
//! training or serving pipeline reads a metric back to make a decision,
//! which is what keeps telemetry off the bit-identity surface.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::sync::lock_unpoisoned;

/// Monotonic event counter (Prometheus `counter`).
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (Prometheus `gauge`) — e.g. queue depth.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn add(&self, n: i64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.value.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn set(&self, n: i64) {
        self.value.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Fixed log-scale-bucket histogram: finite buckets with upper edges
/// `2^min_exp, 2^(min_exp+1), ..., 2^max_exp`, plus one overflow (`+Inf`)
/// bucket.  Values at or below `2^min_exp` land in the first bucket.
#[derive(Debug)]
pub struct Histogram {
    min_exp: i32,
    /// One slot per finite bucket plus the overflow bucket.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Σ observed values, stored as f64 bits (CAS-updated).
    sum_bits: AtomicU64,
}

impl Histogram {
    /// Buckets with upper edges `2^min_exp ..= 2^max_exp` (plus `+Inf`).
    pub fn new(min_exp: i32, max_exp: i32) -> Histogram {
        assert!(min_exp < max_exp, "need at least two finite buckets");
        let finite = (max_exp - min_exp + 1) as usize;
        Histogram {
            min_exp,
            buckets: (0..finite + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Upper edge of finite bucket `i` — exactly `2^(min_exp + i)`.
    fn edge(&self, i: usize) -> f64 {
        2f64.powi(self.min_exp + i as i32)
    }

    pub fn observe(&self, v: f64) {
        let finite = self.buckets.len() - 1;
        let mut idx = finite; // overflow unless a finite edge holds it
        for i in 0..finite {
            if v <= self.edge(i) {
                idx = i;
                break;
            }
        }
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Consistent-enough point-in-time copy (buckets are read one by one;
    /// concurrent observes may straddle the read, which telemetry
    /// tolerates).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let finite = self.buckets.len() - 1;
        HistogramSnapshot {
            edges: (0..finite).map(|i| self.edge(i)).collect(),
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
        }
    }
}

/// Point-in-time histogram contents; percentiles are interpolated within
/// the covering bucket (deterministic given the same counts).
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    /// Upper edges of the finite buckets, ascending.
    pub edges: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `counts.len() == edges.len()+1`
    /// — the last slot is the overflow bucket.
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of all observed values (exact, from the running sum).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Estimated p-th percentile (`p` in 0..=100): linear interpolation
    /// within the bucket covering the rank.  `None` when empty.  Overflow
    /// samples clamp to the top finite edge — the histogram's range is
    /// sized so that regime means "off the scale", not "precision".
    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if next as f64 >= rank {
                if i >= self.edges.len() {
                    return self.edges.last().copied();
                }
                let lo = if i == 0 { 0.0 } else { self.edges[i - 1] };
                let hi = self.edges[i];
                let frac = ((rank - cum as f64) / c as f64).clamp(0.0, 1.0);
                return Some(lo + frac * (hi - lo));
            }
            cum = next;
        }
        self.edges.last().copied()
    }
}

/// What a registry entry is, for the `# TYPE` line and the render shape.
#[derive(Debug, Clone)]
pub(crate) enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// One registered metric: help text, constant labels, and the instrument.
#[derive(Debug, Clone)]
pub(crate) struct Entry {
    pub help: String,
    pub labels: Vec<(String, String)>,
    pub metric: Metric,
}

/// Named metrics for exposition.  Registration order is irrelevant — the
/// map is a `BTreeMap`, so the rendered exposition is deterministic.
#[derive(Debug, Default)]
pub struct Registry {
    entries: Mutex<BTreeMap<String, Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn register(&self, name: &str, help: &str, labels: Vec<(String, String)>, metric: Metric) {
        let mut entries = lock_unpoisoned(&self.entries);
        let prior = entries.insert(
            name.to_string(),
            Entry { help: help.to_string(), labels, metric },
        );
        debug_assert!(prior.is_none(), "metric {name} registered twice");
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let c = Arc::new(Counter::default());
        self.register(name, help, Vec::new(), Metric::Counter(Arc::clone(&c)));
        c
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let g = Arc::new(Gauge::default());
        self.register(name, help, Vec::new(), Metric::Gauge(Arc::clone(&g)));
        g
    }

    pub fn histogram(&self, name: &str, help: &str, min_exp: i32, max_exp: i32) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new(min_exp, max_exp));
        self.register(name, help, Vec::new(), Metric::Histogram(Arc::clone(&h)));
        h
    }

    /// Counter with constant labels (rendered inside `{...}`).
    pub fn counter_with_labels(
        &self,
        name: &str,
        help: &str,
        labels: Vec<(String, String)>,
    ) -> Arc<Counter> {
        let c = Arc::new(Counter::default());
        self.register(name, help, labels, Metric::Counter(Arc::clone(&c)));
        c
    }

    /// Copy of the entry table for rendering.
    pub(crate) fn entries(&self) -> BTreeMap<String, Entry> {
        lock_unpoisoned(&self.entries).clone()
    }

    /// Prometheus text exposition format 0.0.4 (see [`super::prometheus`]).
    pub fn render_prometheus(&self) -> String {
        super::prometheus::render(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotonic_and_gauges_balance() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.add(3);
        g.sub(2);
        assert_eq!(g.get(), 1);
        g.set(0);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn histogram_bucket_edges_are_exact_powers_of_two() {
        let h = Histogram::new(-3, 2);
        let snap = h.snapshot();
        assert_eq!(snap.edges, vec![0.125, 0.25, 0.5, 1.0, 2.0, 4.0]);
        // powi must give the same bits as the literals on every platform.
        for (i, &e) in snap.edges.iter().enumerate() {
            assert_eq!(e.to_bits(), 2f64.powi(-3 + i as i32).to_bits());
        }
        assert_eq!(snap.counts.len(), snap.edges.len() + 1);
    }

    #[test]
    fn observations_land_in_deterministic_buckets() {
        let h = Histogram::new(-3, 2);
        // Exactly on an edge goes to that edge's bucket (le semantics).
        h.observe(0.25);
        // Below the bottom edge clamps into the first bucket.
        h.observe(0.001);
        // Above the top edge goes to overflow.
        h.observe(100.0);
        let snap = h.snapshot();
        assert_eq!(snap.counts, vec![1, 1, 0, 0, 0, 0, 1]);
        assert_eq!(snap.count, 3);
        assert!((snap.sum - 100.251).abs() < 1e-12);
    }

    #[test]
    fn memory_is_bounded_but_count_and_sum_are_all_time() {
        let h = Histogram::new(-10, 0);
        let width = h.snapshot().counts.len();
        for i in 0..100_000u64 {
            h.observe((i % 1000) as f64 * 1e-3);
        }
        let snap = h.snapshot();
        assert_eq!(snap.counts.len(), width, "bucket storage must not grow");
        assert_eq!(snap.count, 100_000, "count is all-time");
        assert_eq!(snap.counts.iter().sum::<u64>(), 100_000);
    }

    #[test]
    fn percentiles_interpolate_within_the_covering_bucket() {
        let h = Histogram::new(-10, -4);
        for i in 1..=10 {
            h.observe(i as f64 * 1e-3); // 1ms ..= 10ms
        }
        let snap = h.snapshot();
        let p50 = snap.percentile(50.0).unwrap();
        assert!(p50 > 0.004 && p50 < 0.007, "p50 {p50}");
        let p99 = snap.percentile(99.0).unwrap();
        assert!(p99 > 0.008 && p99 <= 0.015625, "p99 {p99}");
        assert!((snap.mean() - 0.0055).abs() < 1e-12);
        assert_eq!(Histogram::new(-10, -4).snapshot().percentile(50.0), None);
    }

    #[test]
    fn registry_renders_deterministically_regardless_of_insertion_order() {
        let a = Registry::new();
        a.counter("zz_total", "z");
        a.gauge("aa_depth", "a");
        let b = Registry::new();
        b.gauge("aa_depth", "a");
        b.counter("zz_total", "z");
        assert_eq!(a.render_prometheus(), b.render_prometheus());
    }
}
