//! Span-based stage tracing with Chrome `trace_event` export.
//!
//! Instrumented code opens a [`span`] (or [`span_with`] to attach numeric
//! args such as flop/byte counts) around a pipeline stage; the guard
//! records a `B` event on construction and an `E` event on drop.  The
//! resulting [`Trace`] serializes to the Chrome `trace_event` JSON array
//! format, loadable in `chrome://tracing` or Perfetto.
//!
//! Contracts:
//!
//! * **Zero overhead disabled** — the disabled path is a single relaxed
//!   atomic load; the args closure is never evaluated.  Tracing is off
//!   unless [`enable`] ran.
//! * **Bit-identity** — spans observe timing, they never feed it back:
//!   no instrumented function branches on a clock value, so a traced run
//!   produces bit-identical results to an untraced one (asserted in
//!   `tests/obs.rs`).  The trace clock itself is a
//!   [`crate::util::stats::Timer`] epoch — monotonic, and already blessed
//!   by lint rule D2.
//! * **Bounded memory, matched pairs** — the event buffer has a fixed
//!   cap.  At the cap a new `B` is refused (counted in
//!   [`Trace::dropped`]) so its span records nothing; an `E` is always
//!   appended for every recorded `B`, so written traces have matched
//!   B/E pairs.  A generation counter keeps spans that outlive a
//!   [`disable`]/[`enable`] cycle from writing an unmatched `E` into the
//!   next session.
//!
//! Timestamps are read under the buffer lock, so the event stream is
//! globally ordered: `ts` is non-decreasing per thread (and overall).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::Timer;
use crate::util::sync::lock_unpoisoned;

/// Event-buffer cap: ~1M events (tens of MB serialized) bounds a traced
/// run that forgets to stop.
const EVENT_CAP: usize = 1 << 20;

static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<TraceState>> = Mutex::new(None);
/// Monotonic across enable() calls — never reset, so a stale [`Span`]
/// can't emit into a later session.
static GENERATION: AtomicU64 = AtomicU64::new(0);

/// Stable small thread ids for the `tid` field (allocation order).
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn current_tid() -> u64 {
    TID.with(|t| *t)
}

struct TraceState {
    events: Vec<TraceEvent>,
    /// Monotonic epoch: event `ts` is microseconds since [`enable`].
    epoch: Timer,
    generation: u64,
    dropped: u64,
}

/// B/E phase of a `trace_event` duration event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    B,
    E,
}

/// One recorded event.  `name`/`cat` are `&'static str` so recording
/// never allocates for the common no-args case.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    pub cat: &'static str,
    pub ph: Phase,
    /// Microseconds since [`enable`].
    pub ts_us: f64,
    pub tid: u64,
    /// Numeric args (`flops`, `bytes`, `batch`, ...); only on `B` events.
    pub args: Vec<(&'static str, f64)>,
}

/// Whether tracing is currently recording.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start recording.  Resets the buffer and the epoch; a previous
/// unfinished session's events are discarded.
pub fn enable() {
    let generation = GENERATION.fetch_add(1, Ordering::Relaxed) + 1;
    let mut guard = lock_unpoisoned(&STATE);
    *guard = Some(TraceState {
        events: Vec::new(),
        epoch: Timer::start(),
        generation,
        dropped: 0,
    });
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording and take the buffered trace.  Spans still open keep
/// their guards but record nothing further (their `E` is suppressed by
/// the generation check, keeping the returned trace's pairs matched).
pub fn disable() -> Trace {
    ENABLED.store(false, Ordering::Relaxed);
    let mut guard = lock_unpoisoned(&STATE);
    match guard.take() {
        Some(s) => Trace { events: s.events, dropped: s.dropped },
        None => Trace { events: Vec::new(), dropped: 0 },
    }
}

/// RAII stage guard: `B` on open, `E` on drop.  Inert when tracing is
/// disabled or the buffer is full.
#[must_use = "a span records its duration when dropped"]
pub struct Span {
    /// `(cat, name, generation)` of the recorded `B`, if one was written.
    token: Option<(&'static str, &'static str, u64)>,
}

/// Open a span with no args.
pub fn span(cat: &'static str, name: &'static str) -> Span {
    span_with(cat, name, Vec::new)
}

/// Open a span with numeric args (e.g. flop/byte counts).  `args` is
/// evaluated only when tracing is enabled — keep the disabled path free.
pub fn span_with<F>(cat: &'static str, name: &'static str, args: F) -> Span
where
    F: FnOnce() -> Vec<(&'static str, f64)>,
{
    if !ENABLED.load(Ordering::Relaxed) {
        return Span { token: None };
    }
    let mut guard = lock_unpoisoned(&STATE);
    let Some(state) = guard.as_mut() else {
        return Span { token: None };
    };
    if state.events.len() >= EVENT_CAP {
        state.dropped += 1;
        return Span { token: None };
    }
    let ts_us = state.epoch.secs() * 1e6;
    state.events.push(TraceEvent {
        name,
        cat,
        ph: Phase::B,
        ts_us,
        tid: current_tid(),
        args: args(),
    });
    Span { token: Some((cat, name, state.generation)) }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some((cat, name, generation)) = self.token.take() else {
            return;
        };
        let mut guard = lock_unpoisoned(&STATE);
        let Some(state) = guard.as_mut() else {
            return;
        };
        if state.generation != generation {
            return; // the session that recorded our B is gone
        }
        let ts_us = state.epoch.secs() * 1e6;
        state.events.push(TraceEvent {
            name,
            cat,
            ph: Phase::E,
            ts_us,
            tid: current_tid(),
            args: Vec::new(),
        });
    }
}

/// Per-(cat, name) aggregate over matched B/E pairs — what the bench
/// writers persist as the per-stage breakdown.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTotal {
    pub calls: u64,
    pub total_s: f64,
    pub flops: f64,
    pub bytes: f64,
}

/// A finished recording session.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    /// Spans refused because the buffer hit [`EVENT_CAP`].
    pub dropped: u64,
}

impl Trace {
    /// Chrome `trace_event` JSON (object form: `{"traceEvents": [...]}`),
    /// loadable in `chrome://tracing` and Perfetto.
    pub fn to_chrome_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("name".to_string(), Json::str(e.name)),
                    ("cat".to_string(), Json::str(e.cat)),
                    (
                        "ph".to_string(),
                        Json::str(match e.ph {
                            Phase::B => "B",
                            Phase::E => "E",
                        }),
                    ),
                    ("ts".to_string(), Json::num(e.ts_us)),
                    ("pid".to_string(), Json::num(1.0)),
                    ("tid".to_string(), Json::num(e.tid as f64)),
                ];
                if !e.args.is_empty() {
                    let args: BTreeMap<String, Json> = e
                        .args
                        .iter()
                        .map(|&(k, v)| (k.to_string(), Json::num(v)))
                        .collect();
                    fields.push(("args".to_string(), Json::Obj(args)));
                }
                Json::Obj(fields.into_iter().collect())
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::arr(events)),
            ("displayTimeUnit", Json::str("ms")),
        ])
    }

    /// Write the Chrome-trace JSON to `path`.
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_chrome_json().pretty() + "\n")
            .map_err(|e| anyhow::anyhow!("trace: cannot write {path:?}: {e}"))
    }

    /// Aggregate matched B/E pairs into per-stage totals, keyed
    /// `(cat, name)`.  `flops`/`bytes` args on the `B` event accumulate
    /// into the stage's totals.  Unmatched events (cap truncation at the
    /// very end of a session) are skipped.
    pub fn stage_totals(&self) -> BTreeMap<(String, String), StageTotal> {
        let mut stacks: BTreeMap<u64, Vec<&TraceEvent>> = BTreeMap::new();
        let mut totals: BTreeMap<(String, String), StageTotal> = BTreeMap::new();
        for e in &self.events {
            match e.ph {
                Phase::B => stacks.entry(e.tid).or_default().push(e),
                Phase::E => {
                    let Some(b) = stacks.get_mut(&e.tid).and_then(|s| s.pop()) else {
                        continue;
                    };
                    let t = totals
                        .entry((b.cat.to_string(), b.name.to_string()))
                        .or_default();
                    t.calls += 1;
                    t.total_s += (e.ts_us - b.ts_us) / 1e6;
                    for &(k, v) in &b.args {
                        match k {
                            "flops" => t.flops += v,
                            "bytes" => t.bytes += v,
                            _ => {}
                        }
                    }
                }
            }
        }
        totals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tracer is process-global; serialize the tests that toggle it.
    static TRACE_TEST_LOCK: Mutex<()> = Mutex::new(());

    /// Other unit tests in this binary may run traced code concurrently;
    /// filter the buffer down to this module's unique categories.
    fn own(events: &[TraceEvent], cat: &str) -> Vec<TraceEvent> {
        events.iter().filter(|e| e.cat == cat).cloned().collect()
    }

    #[test]
    fn disabled_spans_record_nothing_and_skip_the_args_closure() {
        let _guard = lock_unpoisoned(&TRACE_TEST_LOCK);
        assert!(!enabled());
        let evaluated = std::cell::Cell::new(false);
        {
            let _sp = span_with("obs-unit-disabled", "noop", || {
                evaluated.set(true);
                vec![("x", 1.0)]
            });
        }
        assert!(!evaluated.get(), "args must not be evaluated while disabled");
        // No session was open, so there is nothing to drain.
        assert!(own(&disable().events, "obs-unit-disabled").is_empty());
    }

    #[test]
    fn spans_nest_into_matched_pairs_with_monotone_timestamps() {
        let _guard = lock_unpoisoned(&TRACE_TEST_LOCK);
        enable();
        {
            let _outer = span("obs-unit-nest", "outer");
            {
                let _inner = span_with("obs-unit-nest", "inner", || {
                    vec![("flops", 8.0), ("bytes", 32.0)]
                });
            }
        }
        let trace = disable();
        let events = own(&trace.events, "obs-unit-nest");
        assert_eq!(events.len(), 4);
        let names: Vec<(&str, Phase)> = events.iter().map(|e| (e.name, e.ph)).collect();
        assert_eq!(
            names,
            vec![
                ("outer", Phase::B),
                ("inner", Phase::B),
                ("inner", Phase::E),
                ("outer", Phase::E),
            ]
        );
        for w in events.windows(2) {
            assert!(w[1].ts_us >= w[0].ts_us, "ts must be non-decreasing");
        }
        let totals = Trace { events, dropped: 0 }.stage_totals();
        let inner = totals[&("obs-unit-nest".to_string(), "inner".to_string())];
        assert_eq!(inner.calls, 1);
        assert_eq!(inner.flops, 8.0);
        assert_eq!(inner.bytes, 32.0);
        assert!(inner.total_s >= 0.0);
    }

    #[test]
    fn a_span_crossing_disable_does_not_leak_an_unmatched_end_event() {
        let _guard = lock_unpoisoned(&TRACE_TEST_LOCK);
        enable();
        let sp = span("obs-unit-gen", "straddle");
        let first = disable();
        assert_eq!(own(&first.events, "obs-unit-gen").len(), 1, "only the B");
        enable();
        drop(sp); // generation mismatch: must not write into the new session
        let second = disable();
        assert!(own(&second.events, "obs-unit-gen").is_empty());
    }

    #[test]
    fn chrome_json_has_the_trace_event_shape() {
        let _guard = lock_unpoisoned(&TRACE_TEST_LOCK);
        enable();
        {
            let _sp = span_with("obs-unit-json", "op", || vec![("flops", 2.0)]);
        }
        let trace = disable();
        let doc = trace.to_chrome_json();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let ours: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("cat").ok().and_then(|c| c.as_str().ok()) == Some("obs-unit-json"))
            .collect();
        assert_eq!(ours.len(), 2);
        for e in &ours {
            for key in ["name", "cat", "ph", "ts", "pid", "tid"] {
                e.get(key).unwrap_or_else(|err| panic!("missing {key}: {err:?}"));
            }
        }
        assert_eq!(ours[0].get("ph").unwrap().as_str().unwrap(), "B");
        assert_eq!(
            ours[0].get("args").unwrap().get("flops").unwrap().as_f64().unwrap(),
            2.0
        );
        assert_eq!(ours[1].get("ph").unwrap().as_str().unwrap(), "E");
    }
}
