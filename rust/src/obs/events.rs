//! Structured event sink: one `name key=value ...` line per event on
//! stdout, wall-clock-stamped.
//!
//! This module owns the repository's **single** reasoned wall-clock read
//! ([`unix_secs`]).  Everything else in the tree times durations through
//! [`crate::util::stats::Timer`] (monotonic), which lint rule D2 blesses;
//! a wall-clock timestamp is only ever attached to log output here, where
//! it can't feed computation or control flow.

/// Seconds since the Unix epoch, for stamping emitted events.
pub fn unix_secs() -> u64 {
    // lint:allow(D2): observability only — the one wall-clock read in the tree; it stamps log events and never feeds computation or control flow
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Emit one structured event line: `name ts=<unix> k=v ...`.
fn emit(name: &str, fields: &[(&str, String)]) {
    let mut line = format!("{name} ts={}", unix_secs());
    for (k, v) in fields {
        line.push(' ');
        line.push_str(k);
        line.push('=');
        line.push_str(v);
    }
    println!("{line}");
}

/// The per-request HTTP log event (`net::server` calls this for every
/// answered request when request logging is on).  Format, stable since
/// the frontend landed:
/// `http ts=<unix> method=<m> route=<path> status=<s> latency_us=<n> batch=<b>`.
pub fn http_request(method: &str, path: &str, status: u16, latency_s: f64, batch: usize) {
    emit(
        "http",
        &[
            ("method", method.to_string()),
            ("route", path.to_string()),
            ("status", status.to_string()),
            ("latency_us", format!("{:.0}", latency_s * 1e6)),
            ("batch", batch.to_string()),
        ],
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unix_secs_is_a_plausible_wall_clock() {
        // 2020-01-01 .. 2100-01-01: catches a zeroed or garbage clock
        // without pinning the test to a date.
        let t = unix_secs();
        assert!(t > 1_577_836_800 && t < 4_102_444_800, "unix_secs() = {t}");
    }
}
