//! Unified observability: a typed metrics registry, span-based stage
//! tracing, Prometheus text exposition, and Chrome-trace profiles — all
//! dependency-free.
//!
//! * [`registry`] — [`Counter`]/[`Gauge`]/[`Histogram`] instruments with
//!   bounded memory and deterministic power-of-two bucket edges, grouped
//!   under a [`Registry`] for exposition.  `serve::metrics` is built on
//!   these.
//! * [`trace`] — [`span`]/[`span_with`] RAII guards around pipeline
//!   stages (sampler draw, layout/pad, per-op kernels with flop/byte
//!   counts, optimizer, serve coalesce/infer).  Zero overhead while
//!   disabled; `hp-gnn train/serve --trace out.json` writes the buffer
//!   as Chrome `trace_event` JSON.
//! * [`prometheus`] — text exposition format 0.0.4 renderer behind
//!   `GET /metrics`.
//! * [`events`] — structured stdout event sink; owns the single reasoned
//!   wall-clock read (`lint:allow(D2)`).
//!
//! The contract threaded through every instrumented layer: telemetry
//! **observes** timing, it never branches on it.  Traced and untraced
//! runs produce bit-identical losses and logits (`tests/obs.rs`), and
//! `obs/` itself sits under the D1/D2 lint contracts like the code it
//! measures.

pub mod events;
pub mod prometheus;
pub mod registry;
pub mod trace;

pub use registry::{Counter, Gauge, Histogram, HistogramSnapshot, Registry};
pub use trace::{span, span_with, Span, Trace};
