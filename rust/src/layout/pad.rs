//! Padding an [`IndexedBatch`] to a fixed [`Geometry`] for the AOT
//! executable.
//!
//! Contract (shared with `python/compile/geometry.py`):
//! * padding edges carry `val == 0` and point at row 0 of both layers —
//!   zero-valued edges contribute nothing;
//! * padding target vertices carry `mask == 0` and label 0;
//! * padding self-gathers point at row 0 (their update output is masked).
//!
//! Subgraph batches can overflow the edge budget (induced density varies);
//! [`EdgeOverflow::TruncateKeepSelf`] drops excess *neighbor* edges while
//! keeping every self loop, preserving aggregation well-definedness — this
//! is the same edge-budget clipping GraphSAINT implementations apply.

use super::{Geometry, IndexedBatch, IndexedLayer};

/// Policy when a layer has more edges than the geometry allows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeOverflow {
    /// Fail — neighbor sampling geometries are sized for the worst case.
    Error,
    /// Keep all self loops, then as many neighbor edges as fit.
    TruncateKeepSelf,
}

/// Execution-ready padded batch; array lengths match the geometry exactly.
#[derive(Debug, Clone)]
pub struct PaddedBatch {
    pub geom: Geometry,
    /// Per layer: src/dst/val of length `geom.e[l]`.
    pub src: Vec<Vec<i32>>,
    pub dst: Vec<Vec<i32>>,
    pub val: Vec<Vec<f32>>,
    /// Per layer: self-gather of length `geom.b[l+1]`.
    pub self_idx: Vec<Vec<i32>>,
    /// Targets: length `geom.b[L]`.
    pub labels: Vec<i32>,
    pub mask: Vec<f32>,
    /// Real (unpadded) per-layer vertex counts.
    pub real_b: Vec<usize>,
    /// Real (possibly truncated) per-layer edge counts.
    pub real_e: Vec<usize>,
    /// Σ real |B^l| — NVTPS numerator for this batch.
    pub vertices_traversed: usize,
}

impl PaddedBatch {
    /// Deterministic synthetic batch filling `geom` exactly — random
    /// edges with a sprinkle of padding (`val == 0`) edges and masked-out
    /// targets.  Test/bench support (the kernel-parity suite and the
    /// hotpath train-step bench share it); real batches come from
    /// [`pad`].
    pub fn synthetic(geom: &Geometry, seed: u64) -> PaddedBatch {
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(seed);
        let ll = geom.layers();
        let mut src = Vec::with_capacity(ll);
        let mut dst = Vec::with_capacity(ll);
        let mut val = Vec::with_capacity(ll);
        let mut self_idx = Vec::with_capacity(ll);
        for l in 0..ll {
            let (b_in, b_out, e) = (geom.b[l], geom.b[l + 1], geom.e[l]);
            src.push((0..e).map(|_| rng.index(b_in) as i32).collect::<Vec<i32>>());
            dst.push((0..e).map(|_| rng.index(b_out) as i32).collect::<Vec<i32>>());
            val.push(
                (0..e)
                    .map(|i| if i % 7 == 0 { 0.0 } else { rng.f32_range(0.05, 1.0) })
                    .collect::<Vec<f32>>(),
            );
            self_idx.push((0..b_out).map(|_| rng.index(b_in) as i32).collect::<Vec<i32>>());
        }
        let classes = geom.num_classes();
        PaddedBatch {
            geom: geom.clone(),
            src,
            dst,
            val,
            self_idx,
            labels: (0..geom.b[ll]).map(|_| rng.index(classes) as i32).collect(),
            mask: (0..geom.b[ll]).map(|i| if i % 9 == 0 { 0.0 } else { 1.0 }).collect(),
            real_b: geom.b.clone(),
            real_e: geom.e.clone(),
            vertices_traversed: geom.b.iter().sum(),
        }
    }
}

/// Pad `batch` (with target labels) to `geom`.
pub fn pad(
    batch: &IndexedBatch,
    labels: &[u8],
    geom: &Geometry,
    overflow: EdgeOverflow,
) -> anyhow::Result<PaddedBatch> {
    let _sp = crate::obs::span("pipeline", "pad");
    geom.validate()?;
    let ll = batch.num_layers();
    anyhow::ensure!(
        ll == geom.layers(),
        "batch has {ll} layers, geometry {} expects {}",
        geom.name,
        geom.layers()
    );
    for l in 0..=ll {
        anyhow::ensure!(
            batch.layers[l].len() <= geom.b[l],
            "layer {l}: {} vertices exceed geometry bound {}",
            batch.layers[l].len(),
            geom.b[l]
        );
    }
    anyhow::ensure!(
        labels.len() == batch.layers[ll].len(),
        "need one label per target vertex"
    );

    let mut src = Vec::with_capacity(ll);
    let mut dst = Vec::with_capacity(ll);
    let mut val = Vec::with_capacity(ll);
    let mut self_idx = Vec::with_capacity(ll);
    let mut real_e = Vec::with_capacity(ll);

    for l in 0..ll {
        let layer = &batch.layer_edges[l];
        let cap = geom.e[l];
        let (s, d, v) = if layer.src.len() <= cap {
            (layer.src.clone(), layer.dst.clone(), layer.val.clone())
        } else {
            match overflow {
                EdgeOverflow::Error => anyhow::bail!(
                    "layer {}: {} edges exceed geometry bound {cap} \
                     (use TruncateKeepSelf for subgraph batches)",
                    l + 1,
                    layer.src.len()
                ),
                EdgeOverflow::TruncateKeepSelf => truncate_keep_self(layer, cap)?,
            }
        };
        real_e.push(s.len());
        let mut s: Vec<i32> = s.into_iter().map(|x| x as i32).collect();
        let mut d: Vec<i32> = d.into_iter().map(|x| x as i32).collect();
        let mut v = v;
        s.resize(cap, 0);
        d.resize(cap, 0);
        v.resize(cap, 0.0);
        src.push(s);
        dst.push(d);
        val.push(v);

        let mut si: Vec<i32> = layer.self_idx.iter().map(|&x| x as i32).collect();
        si.resize(geom.b[l + 1], 0);
        self_idx.push(si);
    }

    let nt = geom.b[ll];
    let mut lab: Vec<i32> = labels.iter().map(|&x| x as i32).collect();
    let real_targets = lab.len();
    lab.resize(nt, 0);
    let mut mask = vec![1.0f32; real_targets];
    mask.resize(nt, 0.0);

    Ok(PaddedBatch {
        geom: geom.clone(),
        src,
        dst,
        val,
        self_idx,
        labels: lab,
        mask,
        real_b: batch.layers.iter().map(|l| l.len()).collect(),
        real_e,
        vertices_traversed: batch.vertices_traversed(),
    })
}

/// Keep all self loops (src position == the dst vertex's self position),
/// then fill with neighbor edges in stream order.
fn truncate_keep_self(
    layer: &IndexedLayer,
    cap: usize,
) -> anyhow::Result<(Vec<u32>, Vec<u32>, Vec<f32>)> {
    let is_self: Vec<bool> = layer
        .src
        .iter()
        .zip(&layer.dst)
        .map(|(&s, &d)| layer.self_idx.get(d as usize) == Some(&s))
        .collect();
    let self_count = is_self.iter().filter(|&&b| b).count();
    anyhow::ensure!(
        self_count <= cap,
        "geometry edge budget {cap} cannot hold {self_count} self loops"
    );
    let mut s = Vec::with_capacity(cap);
    let mut d = Vec::with_capacity(cap);
    let mut v = Vec::with_capacity(cap);
    // Self loops first ...
    for i in 0..layer.src.len() {
        if is_self[i] {
            s.push(layer.src[i]);
            d.push(layer.dst[i]);
            v.push(layer.val[i]);
        }
    }
    // ... then neighbor edges until the budget is full.
    for i in 0..layer.src.len() {
        if s.len() == cap {
            break;
        }
        if !is_self[i] {
            s.push(layer.src[i]);
            d.push(layer.dst[i]);
            v.push(layer.val[i]);
        }
    }
    Ok((s, d, v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::layout::{index_batch, LayoutOptions};
    use crate::sampler::subgraph::SubgraphSampler;
    use crate::sampler::values::{attach_values, GnnModel};
    use crate::sampler::{neighbor::NeighborSampler, Sampler};
    use crate::util::rng::Pcg64;

    fn tiny_geom() -> Geometry {
        Geometry {
            name: "tiny".into(),
            b: vec![96, 16, 4],
            e: vec![96, 16],
            f: vec![16, 8, 4],
        }
    }

    fn ns_batch(seed: u64) -> (IndexedBatch, Vec<u8>) {
        let g = generator::with_min_degree(
            generator::rmat(300, 2500, Default::default(), seed),
            1,
            seed ^ 1,
        );
        let s = NeighborSampler::new(4, vec![5, 3]);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(seed));
        let vals = attach_values(&g, &mb, GnnModel::Gcn);
        let ib = index_batch(&mb, &vals, LayoutOptions::all());
        let labels = vec![1u8; mb.layers[2].len()];
        (ib, labels)
    }

    #[test]
    fn pad_produces_exact_geometry_lengths() {
        let (ib, labels) = ns_batch(1);
        let geom = tiny_geom();
        let pb = pad(&ib, &labels, &geom, EdgeOverflow::Error).unwrap();
        for l in 0..2 {
            assert_eq!(pb.src[l].len(), geom.e[l]);
            assert_eq!(pb.dst[l].len(), geom.e[l]);
            assert_eq!(pb.val[l].len(), geom.e[l]);
            assert_eq!(pb.self_idx[l].len(), geom.b[l + 1]);
        }
        assert_eq!(pb.labels.len(), 4);
        assert_eq!(pb.mask.len(), 4);
        assert_eq!(pb.mask, vec![1.0; 4]); // all 4 targets real
        assert_eq!(pb.vertices_traversed, ib.vertices_traversed());
    }

    #[test]
    fn padding_edges_are_zero_valued(){
        let (ib, labels) = ns_batch(2);
        let geom = tiny_geom();
        let pb = pad(&ib, &labels, &geom, EdgeOverflow::Error).unwrap();
        for l in 0..2 {
            for i in pb.real_e[l]..geom.e[l] {
                assert_eq!(pb.val[l][i], 0.0);
                assert_eq!(pb.src[l][i], 0);
                assert_eq!(pb.dst[l][i], 0);
            }
        }
    }

    #[test]
    fn mask_zero_on_padded_targets() {
        let (ib, mut labels) = ns_batch(3);
        labels.truncate(ib.layers[2].len());
        let geom = tiny_geom();
        let pb = pad(&ib, &labels, &geom, EdgeOverflow::Error).unwrap();
        let real = pb.real_b[2];
        for i in real..geom.b[2] {
            assert_eq!(pb.mask[i], 0.0);
            assert_eq!(pb.labels[i], 0);
        }
    }

    #[test]
    fn oversize_batch_rejected() {
        let (ib, labels) = ns_batch(4);
        let mut geom = tiny_geom();
        geom.b = vec![8, 6, 4]; // too small for b0
        geom.e = vec![96, 16];
        assert!(pad(&ib, &labels, &geom, EdgeOverflow::Error).is_err());
    }

    #[test]
    fn label_count_must_match_targets() {
        let (ib, _) = ns_batch(5);
        let bad = vec![0u8; 1];
        assert!(pad(&ib, &bad, &tiny_geom(), EdgeOverflow::Error).is_err());
    }

    #[test]
    fn subgraph_truncation_keeps_self_loops() {
        let g = generator::rmat(400, 12_000, Default::default(), 6);
        let s = SubgraphSampler::new(64, 2);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(7));
        let vals = attach_values(&g, &mb, GnnModel::Sage);
        let ib = index_batch(&mb, &vals, LayoutOptions::all());
        let n = mb.layers[0].len();
        let raw_edges = ib.layer_edges[0].src.len();
        let cap = n + (raw_edges - n) / 4; // force a real truncation
        let geom = Geometry {
            name: "ss".into(),
            b: vec![64, 64, 64],
            e: vec![cap, cap],
            f: vec![16, 8, 4],
        };
        let labels = vec![0u8; n];
        let err = pad(&ib, &labels, &geom, EdgeOverflow::Error);
        assert!(err.is_err(), "should overflow");
        let pb = pad(&ib, &labels, &geom, EdgeOverflow::TruncateKeepSelf).unwrap();
        assert_eq!(pb.real_e[0], cap);
        // Every vertex's self loop survives: position i gathers from
        // self_idx[i]; check edge (self_idx[i], i) present.
        let l = &ib.layer_edges[0];
        for i in 0..n {
            let want_src = l.self_idx[i] as i32;
            let found = pb.src[0]
                .iter()
                .zip(&pb.dst[0])
                .take(pb.real_e[0])
                .any(|(&s, &d)| s == want_src && d == i as i32);
            assert!(found, "self loop of vertex {i} dropped");
        }
    }

    #[test]
    fn truncation_respects_cap_exactly() {
        let g = generator::rmat(300, 9_000, Default::default(), 8);
        let s = SubgraphSampler::new(48, 1);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(9));
        let vals = attach_values(&g, &mb, GnnModel::Gcn);
        let ib = index_batch(&mb, &vals, LayoutOptions::all());
        let cap = mb.layers[0].len() + 10;
        let geom = Geometry {
            name: "ss1".into(),
            b: vec![48, 48],
            e: vec![cap],
            f: vec![8, 4],
        };
        let pb = pad(&ib, &vec![0u8; 48], &geom, EdgeOverflow::TruncateKeepSelf).unwrap();
        assert_eq!(pb.real_e[0], cap.min(ib.layer_edges[0].src.len()));
        assert_eq!(pb.src[0].len(), cap);
    }
}
