//! Data layout & internal representation (paper §4.1) and geometry padding.
//!
//! The two optimizations evaluated in Table 6:
//!
//! * **RMT** (Reduce Memory Traffic): edges in COO sorted by *source*
//!   vertex so consecutive edges reuse the loaded feature vector — feature
//!   traffic drops from O(|E^1| f^0) to O(|B^0| f^0).
//! * **RRA** (Reduce Random Access): *vertex renaming* labels vertices by
//!   storage order, then edges are re-sorted by the renamed sources, so
//!   hidden-feature reads become sequential.
//!
//! [`index_batch`] turns a global-id [`MiniBatch`] into positional COO
//! (every executable needs positions), recording which optimizations were
//! applied; the accelerator simulator consults those flags to decide
//! whether feature reads are sequential or random (the functional result
//! never changes — the paper's optimizations are timing-only).
//!
//! [`pad`] then pads the indexed batch to a fixed [`Geometry`] for the AOT
//! executable.

pub mod pad;

use crate::graph::Vid;
use crate::sampler::values::EdgeValues;
use crate::sampler::MiniBatch;

/// Fixed shapes of one compiled mini-batch class (mirror of
/// `python/compile/geometry.py`; parsed from the artifact manifest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Geometry {
    pub name: String,
    /// Padded vertex count per layer, `b[0]` input ... `b[L]` targets.
    pub b: Vec<usize>,
    /// Padded edge count per layer (`e[l-1]` connects layers l-1 and l).
    pub e: Vec<usize>,
    /// Feature dims; `f[L]` is the class count.
    pub f: Vec<usize>,
}

impl Geometry {
    pub fn layers(&self) -> usize {
        self.e.len()
    }

    pub fn num_classes(&self) -> usize {
        // lint:allow(R3): validate() rejects geometries with empty f, so last() is Some
        *self.f.last().unwrap()
    }

    /// Σ_l b[l] — padded NVTPS numerator (real batches report their own).
    pub fn total_vertices(&self) -> usize {
        self.b.iter().sum()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.b.len() == self.f.len(), "b/f length mismatch");
        anyhow::ensure!(self.e.len() + 1 == self.b.len(), "e length mismatch");
        anyhow::ensure!(self.layers() >= 1, "at least one layer");
        for l in 1..self.b.len() {
            anyhow::ensure!(self.b[l] <= self.b[l - 1], "b must be non-increasing");
        }
        Ok(())
    }
}

/// Layout optimization switches (Table 6 ablation axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LayoutOptions {
    /// Sort each layer's COO stream by source index (RMT).
    pub rmt: bool,
    /// Rename vertices to storage order before sorting (RRA).  Renaming is
    /// what makes sorted sources *sequential addresses*; without it sorting
    /// still enables register reuse but reads remain scattered.
    pub rra: bool,
}

impl LayoutOptions {
    pub fn all() -> Self {
        LayoutOptions { rmt: true, rra: true }
    }

    pub fn none() -> Self {
        LayoutOptions { rmt: false, rra: false }
    }
}

/// One layer of positional COO: indices into the adjacent layers' vertex
/// lists, plus the SAGE self-index gather.
#[derive(Debug, Clone)]
pub struct IndexedLayer {
    /// Position of the edge source in layer l-1's vertex list.
    pub src: Vec<u32>,
    /// Position of the edge destination in layer l's vertex list.
    pub dst: Vec<u32>,
    pub val: Vec<f32>,
    /// For each layer-l vertex, its position in layer l-1's list.
    pub self_idx: Vec<u32>,
}

/// A mini-batch in positional form, ready for padding/execution and for
/// the accelerator simulator.
#[derive(Debug, Clone)]
pub struct IndexedBatch {
    /// Global ids per layer, in storage order (drives feature fetch).
    pub layers: Vec<Vec<Vid>>,
    pub layer_edges: Vec<IndexedLayer>,
    pub opts: LayoutOptions,
}

impl IndexedBatch {
    pub fn num_layers(&self) -> usize {
        self.layer_edges.len()
    }

    pub fn vertices_traversed(&self) -> usize {
        self.layers.iter().map(|l| l.len()).sum()
    }
}

/// Build the positional representation of `batch` under `opts`.
///
/// Functional semantics are identical for every `opts` value — only the
/// edge *order* (RMT) and the recorded flags (consumed by the timing
/// simulator) change.
pub fn index_batch(
    batch: &MiniBatch,
    values: &EdgeValues,
    opts: LayoutOptions,
) -> IndexedBatch {
    let _sp = crate::obs::span("pipeline", "layout");
    let ll = batch.num_layers();
    assert_eq!(values.len(), ll, "values per layer");

    // Position maps: global id -> storage position per layer.
    let pos_maps: Vec<std::collections::HashMap<Vid, u32>> = batch
        .layers
        .iter()
        .map(|layer| {
            layer
                .iter()
                .enumerate()
                .map(|(i, &v)| (v, i as u32))
                .collect()
        })
        .collect();

    let mut layer_edges = Vec::with_capacity(ll);
    for l in 0..ll {
        let edges = &batch.edges[l];
        let vals = &values[l];
        assert_eq!(edges.len(), vals.len(), "layer {l} edge/value mismatch");

        // Resolve positions once (one hash lookup per endpoint); the sort
        // then runs on cached u64 keys.  Hash lookups inside the sort
        // comparator made this 25x slower (EXPERIMENTS.md §Perf).
        let src_pos: Vec<u32> = edges.iter().map(|e| pos_maps[l][&e.src]).collect();
        let dst_pos: Vec<u32> = edges.iter().map(|e| pos_maps[l + 1][&e.dst]).collect();

        let mut order: Vec<u32> = (0..edges.len() as u32).collect();
        if opts.rmt {
            let keys: Vec<u64> = if opts.rra {
                // RRA: sort by renamed (positional) source — sequential
                // storage-order reads.
                edges
                    .iter()
                    .enumerate()
                    .map(|(i, _)| ((src_pos[i] as u64) << 32) | dst_pos[i] as u64)
                    .collect()
            } else {
                // RMT only: sort by *global* source id — register reuse,
                // but addresses stay in graph-id order.
                edges
                    .iter()
                    .map(|e| ((e.src as u64) << 32) | e.dst as u64)
                    .collect()
            };
            order.sort_unstable_by_key(|&i| keys[i as usize]);
        }

        let src = order.iter().map(|&i| src_pos[i as usize]).collect();
        let dst = order.iter().map(|&i| dst_pos[i as usize]).collect();
        let val = order.iter().map(|&i| vals[i as usize]).collect();
        let self_idx = batch.layers[l + 1]
            .iter()
            .map(|v| pos_maps[l][v])
            .collect();

        layer_edges.push(IndexedLayer { src, dst, val, self_idx });
    }

    IndexedBatch { layers: batch.layers.clone(), layer_edges, opts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::sampler::neighbor::NeighborSampler;
    use crate::sampler::values::{attach_values, GnnModel};
    use crate::sampler::Sampler;
    use crate::util::rng::Pcg64;

    fn setup() -> (crate::graph::Graph, MiniBatch, EdgeValues) {
        let g = generator::with_min_degree(
            generator::rmat(300, 2500, Default::default(), 8),
            1,
            9,
        );
        let s = NeighborSampler::new(8, vec![4, 3]);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(10));
        let vals = attach_values(&g, &mb, GnnModel::Gcn);
        (g, mb, vals)
    }

    /// Dense aggregation over an indexed layer — reference semantics.
    fn aggregate_positions(layer: &IndexedLayer, num_in: usize, num_out: usize) -> Vec<f64> {
        // Feature = one-hot of source position; output row v collects
        // weighted source positions — enough to detect any wiring change.
        let mut out = vec![0.0f64; num_out];
        for ((&s, &d), &v) in layer.src.iter().zip(&layer.dst).zip(&layer.val) {
            assert!((s as usize) < num_in && (d as usize) < num_out);
            out[d as usize] += v as f64 * (s as f64 + 1.0);
        }
        out
    }

    #[test]
    fn layout_options_do_not_change_semantics() {
        let (_g, mb, vals) = setup();
        let base = index_batch(&mb, &vals, LayoutOptions::none());
        let rmt = index_batch(&mb, &vals, LayoutOptions { rmt: true, rra: false });
        let all = index_batch(&mb, &vals, LayoutOptions::all());
        for l in 0..mb.num_layers() {
            let n_in = mb.layers[l].len();
            let n_out = mb.layers[l + 1].len();
            let a = aggregate_positions(&base.layer_edges[l], n_in, n_out);
            let b = aggregate_positions(&rmt.layer_edges[l], n_in, n_out);
            let c = aggregate_positions(&all.layer_edges[l], n_in, n_out);
            for i in 0..n_out {
                assert!((a[i] - b[i]).abs() < 1e-9, "layer {l} row {i}");
                assert!((a[i] - c[i]).abs() < 1e-9, "layer {l} row {i}");
            }
        }
    }

    #[test]
    fn rra_sorts_by_position_rmt_by_global_id() {
        let (_g, mb, vals) = setup();
        let rmt = index_batch(&mb, &vals, LayoutOptions { rmt: true, rra: false });
        let all = index_batch(&mb, &vals, LayoutOptions::all());
        for l in 0..mb.num_layers() {
            // RRA: positional sources non-decreasing.
            let src = &all.layer_edges[l].src;
            assert!(src.windows(2).all(|w| w[0] <= w[1]), "rra layer {l} not sorted");
            // RMT without RRA: *global* source ids non-decreasing.
            let global: Vec<Vid> = rmt.layer_edges[l]
                .src
                .iter()
                .map(|&i| mb.layers[l][i as usize])
                .collect();
            assert!(global.windows(2).all(|w| w[0] <= w[1]), "rmt layer {l} not sorted");
        }
    }

    #[test]
    fn self_idx_points_to_same_vertex() {
        let (_g, mb, vals) = setup();
        let ib = index_batch(&mb, &vals, LayoutOptions::all());
        for l in 0..mb.num_layers() {
            for (i, &p) in ib.layer_edges[l].self_idx.iter().enumerate() {
                assert_eq!(mb.layers[l][p as usize], mb.layers[l + 1][i]);
            }
        }
    }

    #[test]
    fn unsorted_baseline_preserves_sampler_order() {
        let (_g, mb, vals) = setup();
        let base = index_batch(&mb, &vals, LayoutOptions::none());
        // First edge must be the sampler's first edge (self loop of the
        // first frontier vertex).
        let first = mb.edges[0][0];
        let l0 = &base.layer_edges[0];
        assert_eq!(mb.layers[0][l0.src[0] as usize], first.src);
        assert_eq!(mb.layers[1][l0.dst[0] as usize], first.dst);
    }

    #[test]
    fn geometry_validation() {
        let good = Geometry {
            name: "t".into(),
            b: vec![96, 16, 4],
            e: vec![96, 16],
            f: vec![16, 8, 4],
        };
        good.validate().unwrap();
        assert_eq!(good.layers(), 2);
        assert_eq!(good.num_classes(), 4);
        assert_eq!(good.total_vertices(), 116);
        let bad = Geometry { b: vec![4, 16], ..good.clone() };
        assert!(bad.validate().is_err());
    }
}

#[cfg(test)]
mod figure4_tests {
    //! The paper's Fig. 4 worked example: the data layout pipeline on a
    //! concrete hand-checkable batch.

    use super::*;
    use crate::sampler::{Edge, MiniBatch};

    /// Layer-1 style batch: 4 destinations pulling from 6 sources with
    /// deliberately shuffled sampler order and non-contiguous global ids.
    fn fig4_batch() -> (MiniBatch, crate::sampler::values::EdgeValues) {
        // Global ids chosen so storage order != id order.
        let b0 = vec![7u32, 1, 9, 3, 12, 5];
        let b1 = vec![9u32, 3, 7, 1];
        let edges = vec![
            // (src, dst) in "arrival" order — scattered on purpose.
            Edge { src: 12, dst: 9 },
            Edge { src: 7, dst: 3 },
            Edge { src: 9, dst: 9 },   // self loop
            Edge { src: 3, dst: 3 },   // self loop
            Edge { src: 1, dst: 7 },
            Edge { src: 7, dst: 7 },   // self loop
            Edge { src: 5, dst: 1 },
            Edge { src: 1, dst: 1 },   // self loop
            Edge { src: 12, dst: 1 },
        ];
        let vals = vec![vec![1.0f32; edges.len()]];
        (MiniBatch { layers: vec![b0, b1], edges: vec![edges] }, vals)
    }

    #[test]
    fn renaming_labels_vertices_by_storage_order() {
        let (mb, vals) = fig4_batch();
        let ib = index_batch(&mb, &vals, LayoutOptions::all());
        let l = &ib.layer_edges[0];
        // RRA: sources sorted by *position* (storage order), i.e. the
        // renamed stream reads hidden features sequentially.
        assert!(l.src.windows(2).all(|w| w[0] <= w[1]), "{:?}", l.src);
        // First edges come from position 0 = global vertex 7.
        assert_eq!(mb.layers[0][l.src[0] as usize], 7);
        // Self-loop wiring survives the rename: for each dst position i,
        // the self edge (self_idx[i] -> i) is in the stream.
        for (i, &p) in l.self_idx.iter().enumerate() {
            assert!(
                l.src.iter().zip(&l.dst).any(|(&s, &d)| s == p && d == i as u32),
                "self loop of dst {i} lost"
            );
        }
    }

    #[test]
    fn rmt_only_sorts_by_global_id_like_fig4_layer1() {
        let (mb, vals) = fig4_batch();
        let ib = index_batch(&mb, &vals, LayoutOptions { rmt: true, rra: false });
        let l = &ib.layer_edges[0];
        let globals: Vec<u32> = l.src.iter().map(|&p| mb.layers[0][p as usize]).collect();
        // Fig. 4's layer-1 order: edges grouped by source *id* (1,1,3,5,
        // 7,7,9,12,12) so a loaded feature vector is reused by the
        // following edges with the same source.
        assert_eq!(globals, vec![1, 1, 3, 5, 7, 7, 9, 12, 12]);
        // Positions are NOT monotone (ids 1,3,5 live at positions 1,3,5
        // while id 7 is position 0) — which is exactly the random hidden-
        // feature access RRA then removes.
        assert!(!l.src.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn unsorted_baseline_keeps_arrival_order() {
        let (mb, vals) = fig4_batch();
        let ib = index_batch(&mb, &vals, LayoutOptions::none());
        let l = &ib.layer_edges[0];
        let first_globals: Vec<u32> =
            l.src.iter().take(3).map(|&p| mb.layers[0][p as usize]).collect();
        assert_eq!(first_globals, vec![12, 7, 9]);
    }
}
