//! Minimal, strict JSON parser and writer.
//!
//! Replaces `serde_json` (unavailable in the offline build image).  Covers
//! the full JSON grammar except `\u` surrogate pairs beyond the BMP, which
//! none of our documents (artifact manifests, user programs, metrics dumps)
//! contain.  Numbers parse as `f64`; integer accessors validate losslessness.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Objects use `BTreeMap` for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse / access error with byte offset context.
#[derive(Debug)]
pub enum JsonError {
    Parse { offset: usize, msg: String },
    Access(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            JsonError::Access(msg) => write!(f, "json access error: {msg}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Access(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Access(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > (1u64 << 53) as f64 {
            return Err(JsonError::Access(format!("{n} is not a usize")));
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            return Err(JsonError::Access(format!("{n} is not an integer")));
        }
        Ok(n as i64)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Access(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Access(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Access(format!("expected object, got {other:?}"))),
        }
    }

    /// Object field access with a path-aware error message.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Access(format!("missing key {key:?}")))
    }

    /// `get` that tolerates absence.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn usize_list(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize with 1-space indentation (matches python `json.dump(indent=1)`
    /// closely enough for diffing; not byte-identical).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Compact single-line serialization.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < (1u64 << 53) as f64 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !items.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !map.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.compact())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError::Parse { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(format!("expected literal {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("surrogate \\u escape unsupported"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control character in string")),
                Some(c) => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        // lint:allow(R3): the scanned slice holds only ASCII digits/signs, so from_utf8 cannot fail
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| self.err(format!("bad number {text:?}: {e}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_manifest_like_document() {
        let doc = r#"{"version": 1, "artifacts": [{"name": "gcn_tiny", "shape": [64, 16], "lr": 0.05, "ok": true, "none": null}]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "gcn_tiny");
        assert_eq!(arts[0].get("shape").unwrap().usize_list().unwrap(), vec![64, 16]);
        assert!((arts[0].get("lr").unwrap().as_f64().unwrap() - 0.05).abs() < 1e-12);
        assert!(arts[0].get("ok").unwrap().as_bool().unwrap());
        assert_eq!(*arts[0].get("none").unwrap(), Json::Null);
        // parse(pretty(x)) == x
        let again = Json::parse(&v.pretty()).unwrap();
        assert_eq!(again, v);
    }

    #[test]
    fn parses_real_python_manifest() {
        // The exact shape python's json.dump(indent=1) produces.
        let doc = "{\n \"a\": [\n  1,\n  2\n ],\n \"b\": \"x\"\n}";
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().usize_list().unwrap(), vec![1, 2]);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndA\t");
        let round = Json::parse(&v.compact()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn numbers() {
        for (text, want) in [
            ("0", 0.0),
            ("-12", -12.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5e-2", -0.025),
        ] {
            assert_eq!(Json::parse(text).unwrap().as_f64().unwrap(), want, "{text}");
        }
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,]", "{\"a\":}", "tru", "\"unterminated", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn integer_accessor_guards() {
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("-3").unwrap().as_i64().unwrap(), -3);
    }

    #[test]
    fn missing_key_error_names_key() {
        let v = Json::parse("{\"a\": 1}").unwrap();
        let err = v.get("b").unwrap_err().to_string();
        assert!(err.contains("\"b\""), "{err}");
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(v.compact(), r#"{"a":2,"z":1}"#);
    }
}
