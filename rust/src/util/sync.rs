//! Poison-recovering lock helpers shared by serving and training.
//!
//! A panicked thread poisons the lock it held, but every mutex these
//! helpers guard in this codebase protects data that stays structurally
//! valid mid-update (cache map + ring, metrics sample windows, an
//! `Option<Sender>`, the producer claim window's consumed counter), so
//! the right response is to keep going with the last written state — not
//! to cascade the panic through every worker, producer, and client
//! thread.  This is the blessed alternative the R3
//! no-panic-reachable-from-serving contract points at (`hp-gnn lint`).

use std::sync::{Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Acquire a mutex, recovering from poisoning.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock_unpoisoned`], read half of an `RwLock` (same rationale).
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock_unpoisoned`], write half of an `RwLock` (same rationale).
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn lock_helpers_recover_from_poisoning() {
        let m = Arc::new(Mutex::new(5u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        assert_eq!(*lock_unpoisoned(&m), 5, "last written state survives");

        let l = Arc::new(RwLock::new(7u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert!(l.read().is_err(), "rwlock must actually be poisoned");
        assert_eq!(*read_unpoisoned(&l), 7);
        *write_unpoisoned(&l) = 8;
        assert_eq!(*read_unpoisoned(&l), 8);
    }
}
