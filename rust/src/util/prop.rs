//! Miniature property-testing harness (offline stand-in for `proptest`).
//!
//! Usage pattern mirrors proptest's closure style: a [`Runner`] drives N
//! random cases through a generator function and a property; on failure it
//! re-raises with the case index and a debug rendering of the failing input
//! so the case is reproducible from the fixed seed.

use super::rng::Pcg64;

/// Property runner with a fixed seed and case count.
pub struct Runner {
    pub cases: usize,
    pub seed: u64,
}

/// Default seed — ASCII "HPGN".
pub const DEFAULT_SEED: u64 = 0x4850_474e;

impl Default for Runner {
    fn default() -> Self {
        Runner { cases: 64, seed: DEFAULT_SEED }
    }
}

impl Runner {
    pub fn new(cases: usize, seed: u64) -> Self {
        Runner { cases, seed }
    }

    /// Run `prop` against `cases` inputs drawn by `gen`.
    ///
    /// Panics with the failing case rendered via `Debug` so it can be
    /// reproduced (generators are deterministic in `(seed, case_index)`).
    pub fn run<T: std::fmt::Debug>(
        &self,
        gen: impl Fn(&mut Pcg64) -> T,
        prop: impl Fn(&T) -> Result<(), String>,
    ) {
        for case in 0..self.cases {
            let mut rng = Pcg64::seed_from_u64(self.seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15));
            let input = gen(&mut rng);
            if let Err(msg) = prop(&input) {
                panic!(
                    "property failed at case {case}/{} (seed {:#x}):\n  input: {input:?}\n  {msg}",
                    self.cases, self.seed
                );
            }
        }
    }
}

// --- generator helpers ------------------------------------------------------

/// Random vector of length in `[min_len, max_len]` with elements in `[0, bound)`.
pub fn vec_below(rng: &mut Pcg64, min_len: usize, max_len: usize, bound: u64) -> Vec<u64> {
    let len = min_len + rng.index(max_len - min_len + 1);
    (0..len).map(|_| rng.below(bound)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0usize);
        Runner::new(10, 1).run(
            |rng| rng.below(100),
            |_| {
                counter.set(counter.get() + 1);
                Ok(())
            },
        );
        assert_eq!(counter.get(), 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        Runner::new(50, 2).run(|rng| rng.below(10), |x| {
            if *x < 9 {
                Ok(())
            } else {
                Err("hit nine".into())
            }
        });
    }

    #[test]
    fn deterministic_inputs_per_seed() {
        let collect = |seed| {
            let mut v = Vec::new();
            let cell = std::cell::RefCell::new(&mut v);
            Runner::new(5, seed).run(
                |rng| rng.below(1000),
                |x| {
                    cell.borrow_mut().push(*x);
                    Ok(())
                },
            );
            v
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }

    #[test]
    fn vec_below_respects_bounds() {
        let mut rng = Pcg64::seed_from_u64(3);
        for _ in 0..100 {
            let v = vec_below(&mut rng, 2, 9, 50);
            assert!(v.len() >= 2 && v.len() <= 9);
            assert!(v.iter().all(|&x| x < 50));
        }
    }
}
