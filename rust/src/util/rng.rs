//! Deterministic PRNG substrate (the offline image has no `rand` crate).
//!
//! [`Pcg64`] is the PCG-XSL-RR 128/64 generator — the same algorithm as
//! `rand_pcg::Pcg64` — giving high-quality streams with 2^127 period and
//! O(1) state.  Every sampler, graph generator and property test seeds one
//! explicitly, so all experiments in EXPERIMENTS.md are bit-reproducible.

/// PCG-XSL-RR 128/64.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed from a 64-bit value (stream fixed; distinct seeds -> distinct
    /// sequences).
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into 128-bit state/stream.
        let mut sm = SplitMix64 { state: seed };
        let state = ((sm.next() as u128) << 64) | sm.next() as u128;
        let stream = ((sm.next() as u128) << 64) | sm.next() as u128;
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_add(state);
        rng.next_u64();
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (used for synthetic features).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).  Floyd's
    /// algorithm: O(k) expected, no O(n) allocation.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `k` items from `[0, n)` *with* replacement.
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.index(n)).collect()
    }
}

/// SplitMix64 — seed expander and cheap inner PRNG.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    pub state: u64,
}

impl SplitMix64 {
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        let mut c = Pcg64::seed_from_u64(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn f64_in_unit_interval_with_good_mean() {
        let mut rng = Pcg64::seed_from_u64(2);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 100_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from_u64(3);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Pcg64::seed_from_u64(4);
        for (n, k) in [(10, 10), (100, 7), (5, 0), (1, 1), (1000, 999)] {
            let s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    #[should_panic(expected = "k=6 > n=5")]
    fn sample_distinct_rejects_oversample() {
        Pcg64::seed_from_u64(0).sample_distinct(5, 6);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely identity");
    }
}
