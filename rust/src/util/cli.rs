//! Declarative command-line flag parser (offline stand-in for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, per-flag help text and an auto-generated `--help` screen.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    takes_value: bool,
}

/// Builder + result of a parse.  Typical use (`no_run`: the same flow is
/// covered by the unit tests below):
///
/// ```no_run
/// # use hp_gnn::util::cli::Args;
/// let args = Args::new("demo", "demo tool")
///     .flag("model", "gcn", "GNN model (gcn|sage)")
///     .flag("steps", "100", "training steps")
///     .switch("verbose", "log every batch")
///     .parse_from(vec!["--model".into(), "sage".into()])
///     .unwrap();
/// assert_eq!(args.get("model"), "sage");
/// assert_eq!(args.usize("steps"), 100);
/// assert!(!args.on("verbose"));
/// ```
#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
            values: BTreeMap::new(),
            switches: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Register a value flag with a default.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            takes_value: true,
        });
        self.values.insert(name.to_string(), default.to_string());
        self
    }

    /// Register a boolean switch (default off).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            takes_value: false,
        });
        self.switches.insert(name.to_string(), false);
        self
    }

    /// Parse `std::env::args()` (skipping argv[0]); prints help and exits
    /// on `--help`.
    pub fn parse(self) -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        if argv.iter().any(|a| a == "--help" || a == "-h") {
            eprintln!("{}", self.help_text());
            std::process::exit(0);
        }
        self.parse_from(argv)
    }

    /// Parse an explicit argv (no exit-on-help; used by tests).
    pub fn parse_from(mut self, argv: Vec<String>) -> anyhow::Result<Args> {
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if self.switches.contains_key(&name) {
                    if inline.is_some() {
                        anyhow::bail!("switch --{name} takes no value");
                    }
                    self.switches.insert(name, true);
                } else if self.values.contains_key(&name) {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?,
                    };
                    self.values.insert(name, value);
                } else {
                    anyhow::bail!("unknown flag --{name}\n{}", self.help_text());
                }
            } else {
                self.positional.push(arg);
            }
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag {name:?} was never registered"))
    }

    pub fn on(&self, name: &str) -> bool {
        *self
            .switches
            .get(name)
            .unwrap_or_else(|| panic!("switch {name:?} was never registered"))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name} wants an unsigned integer: {e}"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name} wants a number: {e}"))
    }

    pub fn f32(&self, name: &str) -> f32 {
        self.f64(name) as f32
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nFLAGS:\n", self.program, self.about);
        for spec in &self.specs {
            let default = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let value = if spec.takes_value { " <value>" } else { "" };
            s.push_str(&format!("  --{}{value}\n      {}{default}\n", spec.name, spec.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Args {
        Args::new("t", "test")
            .flag("model", "gcn", "model")
            .flag("steps", "10", "steps")
            .switch("fast", "go fast")
    }

    #[test]
    fn defaults_apply() {
        let a = demo().parse_from(vec![]).unwrap();
        assert_eq!(a.get("model"), "gcn");
        assert_eq!(a.usize("steps"), 10);
        assert!(!a.on("fast"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = demo()
            .parse_from(vec!["--model".into(), "sage".into(), "--steps=25".into(), "--fast".into()])
            .unwrap();
        assert_eq!(a.get("model"), "sage");
        assert_eq!(a.usize("steps"), 25);
        assert!(a.on("fast"));
    }

    #[test]
    fn positional_collected() {
        let a = demo().parse_from(vec!["train".into(), "--fast".into()]).unwrap();
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(demo().parse_from(vec!["--nope".into()]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(demo().parse_from(vec!["--model".into()]).is_err());
    }

    #[test]
    fn switch_with_value_rejected() {
        assert!(demo().parse_from(vec!["--fast=yes".into()]).is_err());
    }

    #[test]
    fn help_mentions_flags() {
        let h = demo().help_text();
        assert!(h.contains("--model") && h.contains("--fast") && h.contains("default: gcn"));
    }
}
