//! Timers and summary statistics for the metrics / benchmarking path.

use std::time::{Duration, Instant};

/// Simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Online running summary (Welford) plus retained samples for percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            self.m2 / (self.samples.len() - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by linear interpolation (p in [0, 100]).  `None` when no
    /// samples have been recorded — an idle serving metrics window must
    /// report "no data", not crash the server.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        Some(if lo == hi {
            sorted[lo]
        } else {
            let w = rank - lo as f64;
            sorted[lo] * (1.0 - w) + sorted[hi] * w
        })
    }

    pub fn median(&self) -> Option<f64> {
        self.percentile(50.0)
    }

    /// Fold another summary's samples into this one — equivalent to
    /// having [`add`](Self::add)ed every sample individually (used to
    /// combine per-thread latency summaries after a load run).
    pub fn merge(&mut self, other: &Summary) {
        for &x in &other.samples {
            self.add(x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        let naive_var = xs.iter().map(|x| (x - 5.0) * (x - 5.0)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((s.var() - naive_var).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.median().unwrap() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0).unwrap() - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0).unwrap() - 100.0).abs() < 1e-9);
        assert!((s.percentile(90.0).unwrap() - 90.1).abs() < 1e-9);
    }

    #[test]
    fn single_sample() {
        let mut s = Summary::new();
        s.add(3.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.var(), 0.0);
        assert_eq!(s.median(), Some(3.0));
    }

    #[test]
    fn empty_summary_has_no_percentiles() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert!(s.percentile(50.0).is_none());
        assert!(s.median().is_none());
        assert!(s.percentile(99.0).is_none());
    }

    #[test]
    fn merge_matches_adding_individually() {
        let (mut a, mut b, mut all) = (Summary::new(), Summary::new(), Summary::new());
        for i in 0..50 {
            let x = (i as f64).sin();
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
            all.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert_eq!(a.percentile(99.0), all.percentile(99.0));
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.secs() >= 0.002);
    }
}
