//! Measurement harness for `cargo bench` targets (criterion is not in the
//! offline image).
//!
//! Each bench target (`rust/benches/table*.rs`, `harness = false`) builds a
//! [`BenchSet`], times closures with warmup + repeated samples, and prints
//! paper-style rows.  Results are also appended as JSON lines to
//! `target/bench-results.jsonl` so the perf pass can diff before/after.

use std::io::Write;
use std::time::Instant;

use super::json::Json;
use super::stats::Summary;

/// One timed measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub median_s: f64,
    pub samples: usize,
    /// Optional derived metric (e.g. NVTPS) with its unit.
    pub metric: Option<(f64, String)>,
}

/// Bench runner: warms up, then samples until both `min_samples` and
/// `min_time_s` are met.
pub struct Bench {
    pub warmup: usize,
    pub min_samples: usize,
    pub max_samples: usize,
    pub min_time_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, min_samples: 5, max_samples: 50, min_time_s: 0.5 }
    }
}

impl Bench {
    /// Quick profile for slow end-to-end cases.
    pub fn quick() -> Self {
        Bench { warmup: 1, min_samples: 3, max_samples: 10, min_time_s: 0.1 }
    }

    /// Time `f`, which returns a value that is black-boxed to prevent DCE.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut summary = Summary::new();
        let start = Instant::now();
        while summary.count() < self.max_samples
            && (summary.count() < self.min_samples
                || start.elapsed().as_secs_f64() < self.min_time_s)
        {
            let t = Instant::now();
            black_box(f());
            summary.add(t.elapsed().as_secs_f64());
        }
        Measurement {
            name: name.to_string(),
            mean_s: summary.mean(),
            std_s: summary.std(),
            median_s: summary
                .median()
                .expect("bench runs record at least min_samples >= 1 samples"),
            samples: summary.count(),
            metric: None,
        }
    }
}

/// Opaque value sink (stable `std::hint::black_box`).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A named collection of measurements with table-style printing.
pub struct BenchSet {
    pub title: String,
    pub rows: Vec<Measurement>,
}

impl BenchSet {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        BenchSet { title: title.to_string(), rows: Vec::new() }
    }

    pub fn push(&mut self, mut m: Measurement, metric: Option<(f64, &str)>) {
        m.metric = metric.map(|(v, u)| (v, u.to_string()));
        let metric_str = m
            .metric
            .as_ref()
            .map(|(v, u)| format!("  {} {u}", super::si(*v)))
            .unwrap_or_default();
        println!(
            "{:<44} {:>10.4} ms ±{:>7.4} ({} samples){}",
            m.name,
            m.mean_s * 1e3,
            m.std_s * 1e3,
            m.samples,
            metric_str
        );
        self.rows.push(m);
    }

    /// Print a free-form table row (for analytic/simulated values that are
    /// not wall-clock measurements).
    pub fn row(&mut self, name: &str, value: f64, unit: &str) {
        println!("{:<44} {:>14} {unit}", name, super::si(value));
        self.rows.push(Measurement {
            name: name.to_string(),
            mean_s: 0.0,
            std_s: 0.0,
            median_s: 0.0,
            samples: 0,
            metric: Some((value, unit.to_string())),
        });
    }

    /// Append results to `target/bench-results.jsonl` (best effort).
    pub fn persist(&self) {
        let path = std::path::Path::new("target").join("bench-results.jsonl");
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) else {
            return;
        };
        for m in &self.rows {
            let mut pairs = vec![
                ("bench", Json::str(self.title.clone())),
                ("name", Json::str(m.name.clone())),
                ("mean_s", Json::num(m.mean_s)),
                ("median_s", Json::num(m.median_s)),
                ("std_s", Json::num(m.std_s)),
                ("samples", Json::num(m.samples as f64)),
            ];
            if let Some((v, u)) = &m.metric {
                pairs.push(("metric", Json::num(*v)));
                pairs.push(("metric_unit", Json::str(u.clone())));
            }
            let _ = writeln!(f, "{}", Json::obj(pairs).compact());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_min_samples() {
        let b = Bench { warmup: 0, min_samples: 4, max_samples: 8, min_time_s: 0.0 };
        let m = b.run("noop", || 1 + 1);
        assert!(m.samples >= 4 && m.samples <= 8);
        assert!(m.mean_s >= 0.0);
    }

    #[test]
    fn run_measures_sleep_roughly() {
        let b = Bench { warmup: 0, min_samples: 3, max_samples: 3, min_time_s: 0.0 };
        let m = b.run("sleep", || std::thread::sleep(std::time::Duration::from_millis(3)));
        assert!(m.mean_s >= 0.003, "{}", m.mean_s);
    }

    #[test]
    fn benchset_accumulates() {
        let mut set = BenchSet::new("test-set");
        set.row("analytic", 1.5e6, "NVTPS");
        let b = Bench { warmup: 0, min_samples: 2, max_samples: 2, min_time_s: 0.0 };
        set.push(b.run("timed", || 42), Some((2.0e6, "NVTPS")));
        assert_eq!(set.rows.len(), 2);
        assert_eq!(set.rows[1].metric.as_ref().unwrap().1, "NVTPS");
    }
}
