//! Utility substrate.
//!
//! The build image has no network and only a minimal vendored crate set
//! (`anyhow`, `log`, plus the optional `xla` backend), so the conveniences
//! a production service would pull from crates.io are implemented here
//! from scratch:
//!
//! * [`json`] — a small, strict JSON parser/writer (manifest + user
//!   programs + metrics dumps).
//! * [`rng`] — PCG64-family deterministic PRNG (samplers, generators).
//! * [`cli`] — declarative flag parser for the `hp-gnn` binary and examples.
//! * [`threadpool`] — scoped worker pool (multi-threaded sampling, §5.1
//!   "Modeling t_sampling").
//! * [`stats`] — timers, running stats, percentiles for the metrics path.
//! * [`bench`] — the measurement harness used by `cargo bench` targets.
//! * [`prop`] — a miniature property-testing harness (proptest analog).
//! * [`sync`] — poison-recovering lock helpers (serving + training).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;

/// Round `x` up to the next multiple of `m` (minimum one block).
pub fn ceil_to(x: usize, m: usize) -> usize {
    assert!(m > 0, "ceil_to with zero block");
    if x == 0 {
        return m;
    }
    x.div_ceil(m) * m
}

/// Human-readable SI formatting for throughput counters (`12.3M`, `456K`).
pub fn si(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.1}K", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_to_rounds_up() {
        assert_eq!(ceil_to(0, 8), 8);
        assert_eq!(ceil_to(1, 8), 8);
        assert_eq!(ceil_to(8, 8), 8);
        assert_eq!(ceil_to(9, 8), 16);
    }

    #[test]
    #[should_panic(expected = "zero block")]
    fn ceil_to_zero_block_panics() {
        ceil_to(4, 0);
    }

    #[test]
    fn si_formats() {
        assert_eq!(si(123.0), "123.0");
        assert_eq!(si(29_270_000.0), "29.27M");
        assert_eq!(si(1_500.0), "1.5K");
        assert_eq!(si(2.5e9), "2.50G");
    }
}
