//! Scoped worker pool for multi-threaded mini-batch sampling.
//!
//! The paper sizes the host sampler pool so `t_sampling < t_GNN` (§5.1);
//! this pool is what the coordinator uses to run that many samplers
//! concurrently.  `std::thread::scope` keeps borrows simple — workers may
//! reference stack data of the caller.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};

use crate::util::sync::lock_unpoisoned;

/// Run `jobs` closures on up to `threads` workers; returns results in job
/// order.  Panics in jobs propagate to the caller (fail fast, like rayon).
pub fn run_jobs<T, F>(threads: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let threads = threads.max(1).min(jobs.len().max(1));
    let n = jobs.len();
    let next = AtomicUsize::new(0);
    // Job storage: each slot is taken exactly once by whichever worker
    // claims its index.
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // lint:allow(R3): fetch_add hands each index to exactly one worker, so the slot is Some
                let job = lock_unpoisoned(&jobs[i]).take().expect("job taken twice");
                let out = job();
                *lock_unpoisoned(&results[i]) = Some(out);
            });
        }
    });

    results
        .into_iter()
        // lint:allow(R3): scope() already propagated any worker panic, so every slot was written
        .map(|m| m.into_inner().unwrap_or_else(PoisonError::into_inner).expect("worker dropped a result"))
        .collect()
}

/// Map `f` over `items` in parallel, preserving order.
pub fn par_map<I, T, F>(threads: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(I) -> T + Send + Sync,
{
    let fref = &f;
    run_jobs(
        threads,
        items
            .into_iter()
            .map(|item| move || fref(item))
            .collect::<Vec<_>>(),
    )
}

/// Available hardware parallelism (1 if unknown).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_job_order() {
        let out = par_map(4, (0..100).collect(), |i: usize| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let count = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..57)
            .map(|_| {
                let c = &count;
                move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect();
        run_jobs(8, jobs);
        assert_eq!(count.load(Ordering::Relaxed), 57);
    }

    #[test]
    fn single_thread_degenerate() {
        let out = par_map(1, vec![1, 2, 3], |x: i32| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<i32> = run_jobs(4, Vec::<fn() -> i32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn can_borrow_caller_stack() {
        let data = vec![10usize, 20, 30];
        let slice = &data[..];
        let out = par_map(2, vec![0usize, 1, 2], |i| slice[i]);
        assert_eq!(out, data);
    }

    #[test]
    fn uses_multiple_threads_when_asked() {
        // Not a strict guarantee, but with blocking jobs all workers engage.
        use std::sync::Barrier;
        let barrier = Barrier::new(4);
        let jobs: Vec<_> = (0..4)
            .map(|_| {
                let b = &barrier;
                move || {
                    b.wait(); // deadlocks unless 4 workers run concurrently
                    1usize
                }
            })
            .collect();
        let out = run_jobs(4, jobs);
        assert_eq!(out.iter().sum::<usize>(), 4);
    }
}
