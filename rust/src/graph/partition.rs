//! Feature-matrix partitioning across DDR channels (paper Fig. 7).
//!
//! "The input feature matrix X is equally partitioned into DDR channels";
//! each die's kernels read mostly from their own channel through the
//! all-to-all interconnect.  The accelerator simulator charges cross-channel
//! reads the interconnect penalty, so the partition map matters for timing.

use super::Vid;

/// Block partition of `num_vertices` rows over `channels` DDR channels.
#[derive(Debug, Clone)]
pub struct ChannelPartition {
    pub num_vertices: usize,
    pub channels: usize,
    /// `bounds[c]..bounds[c+1]` is the vertex range of channel c.
    pub bounds: Vec<usize>,
}

impl ChannelPartition {
    pub fn even(num_vertices: usize, channels: usize) -> Self {
        assert!(channels > 0, "at least one DDR channel");
        let base = num_vertices / channels;
        let rem = num_vertices % channels;
        let mut bounds = Vec::with_capacity(channels + 1);
        bounds.push(0);
        for c in 0..channels {
            let size = base + usize::from(c < rem);
            bounds.push(bounds[c] + size);
        }
        ChannelPartition { num_vertices, channels, bounds }
    }

    /// Which channel holds vertex `v`'s feature row.
    pub fn channel_of(&self, v: Vid) -> usize {
        let v = v as usize;
        assert!(v < self.num_vertices, "vertex {v} out of partition");
        // Channels are near-equal blocks; direct computation beats binary
        // search on the hot path.
        let base = self.num_vertices / self.channels;
        let rem = self.num_vertices % self.channels;
        let big = (base + 1) * rem; // first `rem` channels have base+1 rows
        if base == 0 {
            // More channels than vertices: vertex v lives in channel v.
            return v;
        }
        if v < big {
            v / (base + 1)
        } else {
            rem + (v - big) / base
        }
    }

    pub fn size_of(&self, channel: usize) -> usize {
        self.bounds[channel + 1] - self.bounds[channel]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Runner;

    #[test]
    fn even_partition_covers_all() {
        let p = ChannelPartition::even(103, 4);
        assert_eq!(p.bounds, vec![0, 26, 52, 78, 103]);
        let total: usize = (0..4).map(|c| p.size_of(c)).sum();
        assert_eq!(total, 103);
        // Sizes differ by at most one.
        let sizes: Vec<_> = (0..4).map(|c| p.size_of(c)).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn channel_of_matches_bounds() {
        let p = ChannelPartition::even(1000, 7);
        for v in 0..1000u32 {
            let c = p.channel_of(v);
            assert!(p.bounds[c] <= v as usize && (v as usize) < p.bounds[c + 1], "v={v} c={c}");
        }
    }

    #[test]
    fn property_channel_of_consistent() {
        Runner::new(32, 1).run(
            |rng| (2 + rng.index(5000), 1 + rng.index(8)),
            |&(n, ch)| {
                let p = ChannelPartition::even(n, ch);
                for v in (0..n).step_by((n / 97).max(1)) {
                    let c = p.channel_of(v as Vid);
                    if !(p.bounds[c] <= v && v < p.bounds[c + 1]) {
                        return Err(format!("v={v} mapped to wrong channel {c}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_channels_than_vertices() {
        let p = ChannelPartition::even(3, 8);
        assert_eq!(p.channel_of(0), 0);
        assert_eq!(p.channel_of(2), 2);
    }
}
