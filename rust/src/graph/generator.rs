//! Synthetic graph generators.
//!
//! The paper's datasets (Flickr, Reddit, Yelp, AmazonProducts) are not
//! shipped with this repo; [`rmat`] produces R-MAT/Kronecker-style
//! power-law graphs whose degree skew matches social/e-commerce graphs,
//! and `datasets.rs` instantiates them at the exact |V|, |E| of Table 4.
//! Sampling throughput depends only on (|V|, |E|, degree structure), so
//! this preserves the behaviour the experiments measure (DESIGN.md §2).

use super::{Graph, Vid};
use crate::util::rng::Pcg64;

/// R-MAT parameters. (a, b, c) are the quadrant probabilities; d = 1-a-b-c.
/// Defaults are the Graph500 constants, a well-studied social-graph skew.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Add the reverse of every generated edge (undirected datasets).
    pub symmetric: bool,
}

impl Default for RmatParams {
    fn default() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19, symmetric: true }
    }
}

/// Generate an R-MAT graph with ~`num_edges` directed edges over
/// `num_vertices` vertices (rounded up to a power of two internally, ids
/// taken modulo `num_vertices`).
pub fn rmat(num_vertices: usize, num_edges: usize, params: RmatParams, seed: u64) -> Graph {
    assert!(num_vertices > 1, "rmat needs at least 2 vertices");
    let scale = (num_vertices as f64).log2().ceil() as u32;
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut edges = Vec::with_capacity(if params.symmetric { num_edges * 2 } else { num_edges });
    let gen_count = if params.symmetric { num_edges / 2 } else { num_edges };
    for _ in 0..gen_count.max(1) {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            let (du, dv) = if r < params.a {
                (0, 0)
            } else if r < params.a + params.b {
                (0, 1)
            } else if r < params.a + params.b + params.c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        let u = (u % num_vertices) as Vid;
        let v = (v % num_vertices) as Vid;
        edges.push((u, v));
        if params.symmetric {
            edges.push((v, u));
        }
    }
    Graph::from_edges(num_vertices, &edges)
}

/// Erdős–Rényi-style uniform random graph (baseline generator; used by
/// property tests to exercise samplers on non-skewed structure).
pub fn uniform(num_vertices: usize, num_edges: usize, symmetric: bool, seed: u64) -> Graph {
    let mut rng = Pcg64::seed_from_u64(seed);
    let gen_count = if symmetric { num_edges / 2 } else { num_edges };
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..gen_count.max(1) {
        let u = rng.index(num_vertices) as Vid;
        let v = rng.index(num_vertices) as Vid;
        edges.push((u, v));
        if symmetric {
            edges.push((v, u));
        }
    }
    Graph::from_edges(num_vertices, &edges)
}

/// Ensure a minimum out-degree by wiring a ring through low-degree
/// vertices (prevents dead ends in neighbor sampling on small graphs).
pub fn with_min_degree(g: Graph, min_degree: usize, seed: u64) -> Graph {
    let n = g.num_vertices();
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut edges: Vec<(Vid, Vid)> = Vec::with_capacity(g.num_edges() + n);
    for v in 0..n {
        for &w in g.neighbors(v as Vid) {
            edges.push((v as Vid, w));
        }
        let mut need = min_degree.saturating_sub(g.degree(v as Vid));
        while need > 0 {
            let w = rng.index(n) as Vid;
            if w as usize != v {
                edges.push((v as Vid, w));
                need -= 1;
            }
        }
    }
    let mut out = Graph::from_edges(n, &edges);
    out.feat_dim = g.feat_dim;
    out.num_classes = g.num_classes;
    out.name = g.name;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_has_requested_size() {
        let g = rmat(1000, 8000, RmatParams::default(), 7);
        assert_eq!(g.num_vertices(), 1000);
        // Symmetric generation rounds to even, stays close to target.
        assert!((g.num_edges() as i64 - 8000).abs() <= 2, "{}", g.num_edges());
        g.validate().unwrap();
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(4096, 60_000, RmatParams::default(), 11);
        let mut degs: Vec<usize> = (0..g.num_vertices()).map(|v| g.degree(v as Vid)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        let top1pct: usize = degs[..g.num_vertices() / 100].iter().sum();
        // Power-law: top 1% of vertices hold far more than 1% of edges.
        assert!(
            top1pct as f64 > 0.08 * g.num_edges() as f64,
            "top1% holds {top1pct} of {}",
            g.num_edges()
        );
    }

    #[test]
    fn rmat_deterministic() {
        let a = rmat(512, 4096, RmatParams::default(), 3);
        let b = rmat(512, 4096, RmatParams::default(), 3);
        assert_eq!(a.adj, b.adj);
        let c = rmat(512, 4096, RmatParams::default(), 4);
        assert_ne!(a.adj, c.adj);
    }

    #[test]
    fn uniform_is_flat() {
        let g = uniform(2048, 40_000, true, 5);
        let max_deg = (0..g.num_vertices()).map(|v| g.degree(v as Vid)).max().unwrap();
        // Poisson(≈20): max degree stays moderate, unlike R-MAT.
        assert!(max_deg < 60, "max degree {max_deg}");
    }

    #[test]
    fn with_min_degree_enforces_floor() {
        let g = uniform(256, 300, false, 9);
        let g = with_min_degree(g, 3, 10);
        for v in 0..g.num_vertices() {
            assert!(g.degree(v as Vid) >= 3, "vertex {v}");
        }
    }
}
