//! Dataset registry — Table 4 of the paper, plus reduced variants.
//!
//! Each entry records the *published* statistics (|V|, |E|, f0/f1/f2) and
//! can instantiate a statistic-matched synthetic graph (R-MAT at the same
//! size and an equivalent degree skew).  `scale` produces proportionally
//! reduced instances for the functional training path, keeping average
//! degree constant so sampled mini-batch shapes stay representative.

use super::generator::{self, RmatParams};
use super::Graph;

/// Published statistics of one evaluation dataset (paper Table 4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetSpec {
    pub key: &'static str,
    pub name: &'static str,
    pub nodes: usize,
    pub edges: usize,
    /// GNN-layer dims from Table 4: input features, hidden, classes.
    pub f0: usize,
    pub f1: usize,
    pub f2: usize,
}

pub const FLICKR: DatasetSpec = DatasetSpec {
    key: "FL",
    name: "Flickr",
    nodes: 89_250,
    edges: 899_756,
    f0: 500,
    f1: 256,
    f2: 7,
};

pub const REDDIT: DatasetSpec = DatasetSpec {
    key: "RD",
    name: "Reddit",
    nodes: 232_965,
    edges: 11_606_919,
    f0: 602,
    f1: 256,
    f2: 41,
};

pub const YELP: DatasetSpec = DatasetSpec {
    key: "YP",
    name: "Yelp",
    nodes: 716_847,
    edges: 6_977_410,
    f0: 300,
    f1: 256,
    f2: 100,
};

pub const AMAZON_PRODUCTS: DatasetSpec = DatasetSpec {
    key: "AP",
    name: "AmazonProducts",
    nodes: 1_598_960,
    edges: 132_169_734,
    f0: 200,
    f1: 256,
    f2: 107,
};

/// The paper's four evaluation datasets in Table 4 / 6 / 7 order.
pub const ALL: [DatasetSpec; 4] = [FLICKR, REDDIT, YELP, AMAZON_PRODUCTS];

pub fn by_key(key: &str) -> Option<DatasetSpec> {
    ALL.iter().find(|d| d.key.eq_ignore_ascii_case(key) || d.name.eq_ignore_ascii_case(key)).copied()
}

impl DatasetSpec {
    pub fn avg_degree(&self) -> f64 {
        self.edges as f64 / self.nodes as f64
    }

    /// Feature matrix bytes (f32) — what the paper stores in FPGA DDR.
    pub fn feature_bytes(&self) -> usize {
        self.nodes * self.f0 * 4
    }

    /// Proportionally scaled spec (same average degree and dims).
    pub fn scale(&self, factor: f64) -> ScaledDataset {
        assert!(factor > 0.0 && factor <= 1.0, "scale factor in (0, 1]");
        let nodes = ((self.nodes as f64 * factor) as usize).max(64);
        let edges = ((nodes as f64 * self.avg_degree()) as usize).max(nodes);
        ScaledDataset { spec: *self, nodes, edges }
    }

    /// Full-size synthetic instantiation (statistics of Table 4).
    pub fn instantiate(&self, seed: u64) -> Graph {
        self.scale(1.0).instantiate(seed)
    }
}

/// A (possibly reduced) concrete instantiation target.
#[derive(Debug, Clone, Copy)]
pub struct ScaledDataset {
    pub spec: DatasetSpec,
    pub nodes: usize,
    pub edges: usize,
}

impl ScaledDataset {
    /// Materialize the synthetic graph: R-MAT at (nodes, edges) with a
    /// degree floor of 1 so neighbor sampling never dead-ends.
    pub fn instantiate(&self, seed: u64) -> Graph {
        let g = generator::rmat(self.nodes, self.edges, RmatParams::default(), seed);
        let mut g = generator::with_min_degree(g, 1, seed ^ 0x5ca1e);
        g.feat_dim = self.spec.f0;
        g.num_classes = self.spec.f2;
        g.name = format!("{}@{}", self.spec.key, self.nodes);
        g
    }
}

/// Synthesize input features for a vertex set: class-conditioned Gaussians
/// so that GNN training on the synthetic graph has learnable signal (the
/// e2e example's loss curve must be able to descend).
pub fn synth_features(
    vertices: &[super::Vid],
    labels: &[u8],
    feat_dim: usize,
    num_classes: usize,
    seed: u64,
) -> Vec<f32> {
    assert_eq!(vertices.len(), labels.len());
    let mut out = Vec::with_capacity(vertices.len() * feat_dim);
    let nc = num_classes.max(1);
    for (&v, &label) in vertices.iter().zip(labels) {
        // Per-vertex deterministic stream: features don't depend on batch
        // composition (the FPGA reads the same X rows each time).
        // SplitMix64 + uniform noise of matched std (0.5): the Box-Muller
        // normals cost 10x (ln/cos per element) for no training-signal
        // benefit — EXPERIMENTS.md §Perf.
        let mut sm = crate::util::rng::SplitMix64 {
            state: seed ^ ((v as u64) << 20) ^ label as u64,
        };
        let c = label as usize % nc;
        for j in 0..feat_dim {
            // Class centroid: +1 on dimensions congruent to the class.
            let centroid = if j % nc == c { 1.0f32 } else { 0.0 };
            // Uniform noise, std 0.35 (signal-to-noise tuned so the tiny
            // CI tasks train within a few dozen steps).
            let u = (sm.next() >> 40) as f32 * (1.0 / (1u64 << 24) as f32);
            out.push(centroid + (u - 0.5) * 1.2124356);
        }
    }
    out
}

/// Deterministic per-vertex labels: contiguous id blocks mapped through a
/// seeded class permutation.  Block structure aligns with R-MAT's
/// hierarchical quadrants (vertices sharing id prefixes are preferentially
/// connected), giving the *homophily* real GNN benchmarks have — without
/// it, neighbor aggregation carries no label signal and GCN cannot learn
/// on the synthetic data.
pub fn synth_labels(
    vertices: &[super::Vid],
    num_classes: usize,
    seed: u64,
    num_vertices: usize,
) -> Vec<u8> {
    let nc = num_classes.max(1);
    // Seeded permutation of class ids (labels differ across seeds).
    let mut perm: Vec<u8> = (0..nc as u8).collect();
    let mut rng = crate::util::rng::Pcg64::seed_from_u64(seed ^ 0x1abe15);
    rng.shuffle(&mut perm);
    let n = num_vertices.max(1) as u64;
    vertices
        .iter()
        .map(|&v| {
            let block = ((v as u64) * nc as u64 / n).min(nc as u64 - 1) as usize;
            perm[block]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_table4() {
        assert_eq!(ALL.len(), 4);
        assert_eq!(REDDIT.nodes, 232_965);
        assert_eq!(REDDIT.edges, 11_606_919);
        assert_eq!(REDDIT.f0, 602);
        assert_eq!(AMAZON_PRODUCTS.f2, 107);
        assert!(by_key("rd").unwrap() == REDDIT);
        assert!(by_key("Flickr").unwrap() == FLICKR);
        assert!(by_key("nope").is_none());
    }

    #[test]
    fn scaled_instantiation_matches_stats() {
        let ds = FLICKR.scale(0.02);
        let g = ds.instantiate(1);
        assert_eq!(g.num_vertices(), ds.nodes);
        // Degree floor may add a few edges; stay within 25% of target.
        let target = ds.edges as f64;
        assert!(
            (g.num_edges() as f64) > 0.75 * target && (g.num_edges() as f64) < 1.6 * target,
            "edges {} vs target {target}",
            g.num_edges()
        );
        assert_eq!(g.feat_dim, 500);
        assert_eq!(g.num_classes, 7);
    }

    #[test]
    fn labels_deterministic_and_in_range() {
        let verts: Vec<u32> = (0..1000).collect();
        let a = synth_labels(&verts, 7, 9, 1000);
        let b = synth_labels(&verts, 7, 9, 1000);
        assert_eq!(a, b);
        assert!(a.iter().all(|&l| l < 7));
        // Roughly uniform.
        let mut counts = [0usize; 7];
        for &l in &a {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 80), "{counts:?}");
    }

    #[test]
    fn features_class_conditioned() {
        let verts: Vec<u32> = (0..200).collect();
        let labels = synth_labels(&verts, 4, 3, 200);
        let feats = synth_features(&verts, &labels, 32, 4, 3);
        assert_eq!(feats.len(), 200 * 32);
        // Mean of class-c dimensions exceeds off-class dimensions.
        let mut on = 0.0;
        let mut off = 0.0;
        let (mut n_on, mut n_off) = (0usize, 0usize);
        for (i, &l) in labels.iter().enumerate() {
            for j in 0..32 {
                let x = feats[i * 32 + j] as f64;
                if j % 4 == l as usize {
                    on += x;
                    n_on += 1;
                } else {
                    off += x;
                    n_off += 1;
                }
            }
        }
        assert!(on / n_on as f64 > 0.7 && off / n_off as f64 - 0.0 < 0.3);
    }

    #[test]
    fn features_stable_across_batches() {
        let a = synth_features(&[5, 9], &[1, 2], 8, 4, 7);
        let b = synth_features(&[9], &[2], 8, 4, 7);
        assert_eq!(&a[8..], &b[..], "vertex 9 features depend on batch");
    }

    #[test]
    fn labels_are_homophilous_blocks() {
        let verts: Vec<u32> = (0..1000).collect();
        let labels = synth_labels(&verts, 4, 11, 1000);
        // Adjacent ids share labels except at ~nc block boundaries.
        let changes = labels.windows(2).filter(|w| w[0] != w[1]).count();
        assert!(changes <= 4, "{changes} label changes — not block structured");
        // Different seeds permute the classes.
        let other = synth_labels(&verts, 4, 12, 1000);
        assert_ne!(labels, other);
    }
}
