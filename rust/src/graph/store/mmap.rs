//! Read-only file backing for the out-of-core store: mmap when the
//! platform has it, positional reads (`pread`) as the tested fallback,
//! and a resident buffer for platforms with neither.
//!
//! Dependency-free by design: the mmap binding is a two-symbol
//! `extern "C"` declaration against the libc that `std` already links on
//! unix — no crate added, per the repo's no-new-dependencies rule.

use std::borrow::Cow;
use std::fs::File;
use std::path::Path;

/// Which backing [`open`] should produce.  `Auto` prefers the mmap path
/// and degrades to `Pread` (unix) or `Resident` (elsewhere); the explicit
/// modes exist so tests can pin the fallback paths and assert
/// bit-identity across all of them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackingMode {
    Auto,
    Mmap,
    Pread,
    Resident,
}

/// A read-only window over the packed store file.
#[derive(Debug)]
pub enum Backing {
    /// Kernel-mapped pages; slices borrow straight from the mapping.
    Map(Mapping),
    /// Positional reads against the open file (unix `pread` semantics via
    /// `FileExt::read_exact_at`); every slice is an owned copy.
    #[cfg(unix)]
    Pread { file: File, len: u64 },
    /// The whole file resident in memory (non-unix fallback).
    Resident(Vec<u8>),
}

impl Backing {
    /// Total backing length in bytes.
    pub fn len(&self) -> usize {
        match self {
            Backing::Map(m) => m.len,
            #[cfg(unix)]
            Backing::Pread { len, .. } => *len as usize,
            Backing::Resident(buf) => buf.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many bytes are memory-mapped (0 for the non-mmap backings).
    pub fn bytes_mapped(&self) -> u64 {
        match self {
            Backing::Map(m) => m.len as u64,
            #[cfg(unix)]
            Backing::Pread { .. } => 0,
            Backing::Resident(_) => 0,
        }
    }

    /// `len` bytes at `off`.  Callers pass offsets already validated
    /// against the checked header, so an out-of-range read here means the
    /// file shrank underneath us: degrade to an empty slice (never panic —
    /// this sits under the serving path).
    pub fn slice(&self, off: usize, len: usize) -> Cow<'_, [u8]> {
        let end = match off.checked_add(len) {
            Some(end) if end <= self.len() => end,
            _ => return Cow::Owned(Vec::new()),
        };
        match self {
            Backing::Map(m) => Cow::Borrowed(&m.as_slice()[off..end]),
            #[cfg(unix)]
            Backing::Pread { file, .. } => {
                use std::os::unix::fs::FileExt;
                let mut buf = vec![0u8; len];
                match file.read_exact_at(&mut buf, off as u64) {
                    Ok(()) => Cow::Owned(buf),
                    Err(_) => Cow::Owned(Vec::new()),
                }
            }
            Backing::Resident(buf) => Cow::Borrowed(&buf[off..end]),
        }
    }
}

/// Open `path` read-only under `mode`.  Returns the backing plus the file
/// length (validated elsewhere against the header's section layout).
pub fn open(path: &Path, mode: BackingMode) -> anyhow::Result<Backing> {
    let file = File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open graph store {}: {e}", path.display()))?;
    let len = file.metadata()?.len();
    let len_usize = usize::try_from(len)
        .map_err(|_| anyhow::anyhow!("graph store {} larger than address space", path.display()))?;
    match mode {
        BackingMode::Resident => {
            let buf = std::fs::read(path)?;
            Ok(Backing::Resident(buf))
        }
        #[cfg(unix)]
        BackingMode::Pread => Ok(Backing::Pread { file, len }),
        #[cfg(not(unix))]
        BackingMode::Pread => {
            let buf = std::fs::read(path)?;
            Ok(Backing::Resident(buf))
        }
        BackingMode::Mmap | BackingMode::Auto => {
            #[cfg(unix)]
            {
                match Mapping::map(&file, len_usize) {
                    Ok(m) => Ok(Backing::Map(m)),
                    // Auto degrades (e.g. an empty file, or a filesystem
                    // without mmap); explicit Mmap reports why.
                    Err(e) if mode == BackingMode::Mmap => Err(e),
                    Err(_) => Ok(Backing::Pread { file, len }),
                }
            }
            #[cfg(not(unix))]
            {
                let _ = len_usize;
                let buf = std::fs::read(path)?;
                Ok(Backing::Resident(buf))
            }
        }
    }
}

/// An owned read-only `mmap` region, unmapped on drop.
pub struct Mapping {
    ptr: *const u8,
    len: usize,
}

// The mapping is read-only (PROT_READ, MAP_PRIVATE) and the pointer never
// escapes except through `as_slice`, so sharing across threads is sound.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl std::fmt::Debug for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mapping").field("len", &self.len).finish()
    }
}

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

impl Mapping {
    #[cfg(unix)]
    fn map(file: &File, len: usize) -> anyhow::Result<Mapping> {
        use std::os::unix::io::AsRawFd;
        anyhow::ensure!(len > 0, "cannot mmap an empty file");
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        anyhow::ensure!(
            ptr as isize != -1 && !ptr.is_null(),
            "mmap failed ({})",
            std::io::Error::last_os_error()
        );
        Ok(Mapping { ptr: ptr as *const u8, len })
    }

    pub fn as_slice(&self) -> &[u8] {
        // Sound: the region is PROT_READ for self.len bytes and lives
        // until drop unmaps it.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(unix)]
        unsafe {
            sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("hpgnn-mmap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn every_backing_reads_the_same_bytes() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096 + 13).collect();
        let path = tmpfile("cycle.bin", &data);
        for mode in [BackingMode::Auto, BackingMode::Pread, BackingMode::Resident] {
            let b = open(&path, mode).unwrap();
            assert_eq!(b.len(), data.len(), "{mode:?}");
            assert_eq!(&*b.slice(0, 16), &data[..16], "{mode:?}");
            assert_eq!(&*b.slice(4000, 100), &data[4000..4100], "{mode:?}");
            assert_eq!(&*b.slice(data.len() - 1, 1), &data[data.len() - 1..], "{mode:?}");
        }
    }

    #[test]
    fn out_of_range_slices_degrade_to_empty() {
        let path = tmpfile("short.bin", &[1, 2, 3, 4]);
        for mode in [BackingMode::Auto, BackingMode::Pread, BackingMode::Resident] {
            let b = open(&path, mode).unwrap();
            assert!(b.slice(3, 2).is_empty(), "{mode:?}");
            assert!(b.slice(usize::MAX, 1).is_empty(), "{mode:?}");
            assert!(b.slice(0, usize::MAX).is_empty(), "{mode:?}");
        }
    }

    #[cfg(unix)]
    #[test]
    fn mmap_mode_maps_and_reports_bytes() {
        let data = vec![7u8; 8192];
        let path = tmpfile("mapped.bin", &data);
        let b = open(&path, BackingMode::Mmap).unwrap();
        assert_eq!(b.bytes_mapped(), 8192);
        assert_eq!(&*b.slice(100, 8), &data[100..108]);
    }
}
