//! Out-of-core graph store: mmap-backed CSR snapshots with versioned
//! edge-stream ingest.
//!
//! The in-memory [`crate::graph::Graph`] caps experiments at what fits in
//! RAM; real recommendation graphs (the paper's GraphSAGE setting) do not
//! fit and do not hold still.  This subsystem supplies both missing
//! halves:
//!
//! * **Out-of-core CSR** — [`pack`] writes the chunked `HPGNNG02` format
//!   ([`format`]), and [`GraphStore`] opens it through an mmap (or
//!   `pread` fallback — [`BackingMode`]) without materializing adjacency,
//!   exposing the same [`GraphAccess`] surface samplers already consume.
//!   Neighbor order is preserved bit-for-bit, so a training run from a
//!   packed store reproduces the in-RAM loss curve exactly.
//! * **Dynamic graphs** — [`DynamicGraph`] layers an in-memory edge-delta
//!   over a base store and hands out immutable, versioned
//!   [`GraphSnapshot`]s.  Samplers pin one snapshot per batch; ingest
//!   bumps the version; [`DynamicGraph::compact_to`] folds the delta back
//!   to disk through the same packer.

pub mod format;
mod mmap;
mod snapshot;

use std::borrow::Cow;
use std::path::{Path, PathBuf};

use crate::graph::{GraphAccess, Vid};

pub use format::{pack, PackStats, StoreMeta, DEFAULT_CHUNK_EDGES, STORE_MAGIC};
pub use mmap::BackingMode;
pub use snapshot::{DynamicGraph, GraphSnapshot};

/// A packed `HPGNNG02` graph opened for random access.
///
/// Degrees (the row-pointer array, `8(|V|+1)` bytes) live in RAM; the
/// neighbor section stays on disk behind [`mmap::Backing`] and is touched
/// only by [`GraphAccess::neighbors`] calls.  All reads are positional,
/// so one store can serve many sampler threads without locking.
#[derive(Debug)]
pub struct GraphStore {
    meta: StoreMeta,
    row_ptr: Vec<u64>,
    backing: mmap::Backing,
    path: PathBuf,
}

impl GraphStore {
    /// Open with the default backing (mmap where available).
    pub fn open(path: &Path) -> anyhow::Result<GraphStore> {
        GraphStore::open_with(path, BackingMode::Auto)
    }

    /// Open with an explicit backing mode (tests pin the fallback paths
    /// to prove bit-identity across all of them).
    pub fn open_with(path: &Path, mode: BackingMode) -> anyhow::Result<GraphStore> {
        let backing = mmap::open(path, mode)?;
        let file_len = backing.len();
        let head_len = file_len.min(format::HEADER_BYTES + format::MAX_NAME_BYTES + 8);
        let head = backing.slice(0, head_len);
        let meta = format::read_header(&head, file_len)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;

        let table_len = meta.degree_off - meta.chunk_table_off;
        let table = backing.slice(meta.chunk_table_off, table_len);
        let chunks = format::read_chunk_table(&table, &meta)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;

        let degrees = backing.slice(meta.degree_off, meta.num_vertices * 4);
        let row_ptr = format::read_row_ptr(&degrees, &meta)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;

        // One sequential pass over the chunked neighbor section: every id
        // must be < |V|.  This is the load-time analogue of
        // `Graph::validate`, and it walks the chunk table so a table the
        // header validated but the data contradicts still fails here.
        let _sp = crate::obs::span_with("store", "open", || {
            vec![("bytes", file_len as f64), ("chunks", chunks.len() as f64)]
        });
        for (i, c) in chunks.iter().enumerate() {
            let bytes = backing.slice(c.file_offset as usize, c.nbytes as usize);
            anyhow::ensure!(
                bytes.len() == c.nbytes as usize,
                "{}: chunk {i} unreadable (file shrank?)",
                path.display()
            );
            for (j, win) in bytes.chunks_exact(4).enumerate() {
                let id = u32::from_le_bytes([win[0], win[1], win[2], win[3]]);
                anyhow::ensure!(
                    (id as usize) < meta.num_vertices,
                    "{}: neighbor id {id} at edge {} is out of range (|V|={})",
                    path.display(),
                    c.edge_base as usize + j,
                    meta.num_vertices
                );
            }
        }

        Ok(GraphStore { meta, row_ptr, backing, path: path.to_path_buf() })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }
}

/// Cheap preflight: validate the header of a packed store without mapping
/// or scanning it (80 bytes + the file length).  `hp-gnn validate` uses
/// this to diagnose a missing or malformed `graph.path` before a run
/// starts; a probe that passes can still fail the full neighbor-id scan
/// at [`GraphStore::open`].
pub fn probe(path: &Path) -> anyhow::Result<StoreMeta> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)?;
    let file_len = usize::try_from(f.metadata()?.len())
        .map_err(|_| anyhow::anyhow!("file length does not fit usize"))?;
    let mut head = vec![0u8; file_len.min(format::HEADER_BYTES + format::MAX_NAME_BYTES + 8)];
    f.read_exact(&mut head)?;
    format::read_header(&head, file_len)
}

/// Decode a little-endian u32 byte region into vertex ids, borrowing when
/// the mmap hands back an aligned slice and copying otherwise.
fn bytes_to_vids(bytes: Cow<'_, [u8]>) -> Cow<'_, [Vid]> {
    match bytes {
        #[cfg(target_endian = "little")]
        Cow::Borrowed(b) => {
            // Sound: u32 accepts any bit pattern; align_to only yields a
            // non-empty middle when the pointer is 4-aligned.
            let (pre, mid, suf) = unsafe { b.align_to::<u32>() };
            if pre.is_empty() && suf.is_empty() {
                Cow::Borrowed(mid)
            } else {
                Cow::Owned(decode_vids(b))
            }
        }
        #[cfg(not(target_endian = "little"))]
        Cow::Borrowed(b) => Cow::Owned(decode_vids(b)),
        Cow::Owned(v) => Cow::Owned(decode_vids(&v)),
    }
}

fn decode_vids(b: &[u8]) -> Vec<Vid> {
    b.chunks_exact(4).map(|w| u32::from_le_bytes([w[0], w[1], w[2], w[3]])).collect()
}

impl GraphAccess for GraphStore {
    fn num_vertices(&self) -> usize {
        self.meta.num_vertices
    }

    fn num_edges(&self) -> usize {
        self.meta.num_edges
    }

    fn feat_dim(&self) -> usize {
        self.meta.feat_dim
    }

    fn num_classes(&self) -> usize {
        self.meta.num_classes
    }

    fn graph_name(&self) -> &str {
        &self.meta.name
    }

    fn degree(&self, v: Vid) -> usize {
        let v = v as usize;
        if v >= self.meta.num_vertices {
            return 0;
        }
        (self.row_ptr[v + 1] - self.row_ptr[v]) as usize
    }

    /// Random access into the on-disk neighbor section.  Offsets were
    /// validated at open, so arithmetic here cannot overflow; a read that
    /// still fails (file truncated after open) degrades to an empty list
    /// rather than panicking — this runs under the serving path.
    fn neighbors(&self, v: Vid) -> Cow<'_, [Vid]> {
        let v = v as usize;
        if v >= self.meta.num_vertices {
            return Cow::Owned(Vec::new());
        }
        let start = self.row_ptr[v];
        let nedges = (self.row_ptr[v + 1] - start) as usize;
        if nedges == 0 {
            return Cow::Owned(Vec::new());
        }
        let Some(byte_off) = start
            .checked_mul(4)
            .and_then(|x| x.checked_add(self.meta.neigh_off as u64))
            .and_then(|x| usize::try_from(x).ok())
        else {
            return Cow::Owned(Vec::new());
        };
        let Some(nbytes) = nedges.checked_mul(4) else {
            return Cow::Owned(Vec::new());
        };
        let _sp = crate::obs::span_with("store", "read", || {
            vec![("bytes", nbytes as f64), ("vertex", v as f64)]
        });
        bytes_to_vids(self.backing.slice(byte_off, nbytes))
    }

    fn version(&self) -> u64 {
        self.meta.graph_version
    }

    fn bytes_mapped(&self) -> u64 {
        self.backing.bytes_mapped()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use std::sync::Arc;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("hpgnn-store-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn fixture() -> Graph {
        let mut g = Graph::from_edges(
            6,
            &[(0, 1), (0, 2), (0, 5), (1, 3), (2, 3), (3, 0), (3, 4), (5, 2)],
        );
        g.feat_dim = 16;
        g.num_classes = 4;
        g.name = "store-fixture".into();
        g
    }

    #[test]
    fn store_matches_graph_across_backings() {
        let g = fixture();
        let path = tmp("roundtrip.g2");
        let stats = pack(&g, &path, 0, 3).unwrap();
        assert_eq!(stats.num_edges, g.num_edges());
        for mode in [BackingMode::Auto, BackingMode::Pread, BackingMode::Resident] {
            let s = GraphStore::open_with(&path, mode).unwrap();
            assert_eq!(s.num_vertices(), g.num_vertices(), "{mode:?}");
            assert_eq!(GraphAccess::num_edges(&s), g.num_edges(), "{mode:?}");
            assert_eq!(s.feat_dim(), g.feat_dim, "{mode:?}");
            assert_eq!(s.num_classes(), g.num_classes, "{mode:?}");
            assert_eq!(s.graph_name(), "store-fixture", "{mode:?}");
            for v in 0..g.num_vertices() as Vid {
                assert_eq!(GraphAccess::degree(&s, v), g.degree(v), "{mode:?} v={v}");
                assert_eq!(&*s.neighbors(v), g.neighbors(v), "{mode:?} v={v}");
                assert_eq!(
                    GraphAccess::gcn_norm(&s, v, 0),
                    g.gcn_norm(v, 0),
                    "{mode:?} v={v}"
                );
            }
        }
    }

    #[test]
    fn mmap_backing_reports_mapped_bytes() {
        let path = tmp("mapped.g2");
        pack(&fixture(), &path, 0, DEFAULT_CHUNK_EDGES).unwrap();
        if let Ok(s) = GraphStore::open_with(&path, BackingMode::Mmap) {
            let len = std::fs::metadata(&path).unwrap().len();
            assert_eq!(s.bytes_mapped(), len);
        }
    }

    #[test]
    fn out_of_range_vertex_degrades_not_panics() {
        let path = tmp("oob.g2");
        pack(&fixture(), &path, 0, 3).unwrap();
        let s = GraphStore::open(&path).unwrap();
        assert_eq!(GraphAccess::degree(&s, 999), 0);
        assert!(s.neighbors(999).is_empty());
    }

    #[test]
    fn rejects_out_of_range_neighbor_ids_at_open() {
        let path = tmp("badid.g2");
        pack(&fixture(), &path, 0, 3).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Stomp the last neighbor id with an out-of-range vertex.
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&4_000_000u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = GraphStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn probe_accepts_packed_stores_and_rejects_junk() {
        let path = tmp("probe.g2");
        pack(&fixture(), &path, 3, DEFAULT_CHUNK_EDGES).unwrap();
        let meta = probe(&path).unwrap();
        assert_eq!(meta.num_vertices, 6);
        assert_eq!(meta.graph_version, 3);
        assert!(probe(&tmp("missing.g2")).is_err());
        let junk = tmp("junk.g2");
        std::fs::write(&junk, b"not a graph store at all").unwrap();
        assert!(probe(&junk).is_err());
    }

    #[test]
    fn usable_as_trait_object() {
        let path = tmp("dyn.g2");
        pack(&fixture(), &path, 0, DEFAULT_CHUNK_EDGES).unwrap();
        let s: Arc<dyn GraphAccess> = Arc::new(GraphStore::open(&path).unwrap());
        assert_eq!(s.avg_degree(), fixture().avg_degree());
    }
}
