//! The `HPGNNG02` chunked on-disk CSR format.
//!
//! Layout (all integers little-endian u64 unless noted):
//!
//! ```text
//! offset  field
//! 0       magic  "HPGNNG02"
//! 8       |V|
//! 16      |E|
//! 24      feat_dim
//! 32      num_classes
//! 40      graph_version      (snapshot version baked at pack time)
//! 48      chunk_edges        (edges per chunk, >= 1)
//! 56      num_chunks         (= ceil(|E| / chunk_edges))
//! 64      flags              (bit 0: f32 value section present)
//! 72      name_len           (<= 128 bytes of UTF-8)
//! 80      name bytes, zero-padded to a multiple of 8
//! .       chunk table: num_chunks x { file_offset u64, nbytes u64, edge_base u64 }
//! .       degree section: |V| x u32
//! .       neighbor section (4-byte aligned): |E| x u32, vertex-major, each
//!         vertex's neighbors ascending (duplicates kept) — the exact order
//!         `Graph::from_edges` produces, so sampling is bit-identical
//! .       value section (iff flags bit 0): |E| x f32
//! ```
//!
//! The chunk table is redundant with `(chunk_edges, |E|)` by construction;
//! the loaders verify it **tiles the neighbor section exactly** and reject
//! overlapping, out-of-bounds, or misplaced entries.  Every loader here
//! uses checked arithmetic (lint rule R2 is bound over this module):
//! adversarial headers must fail a length check, never wrap one.

use std::io::Write;
use std::path::Path;

use crate::graph::{GraphAccess, Vid};

/// Magic for the chunked store format.  `HPGNNG01` is the flat in-RAM
/// binary format in [`crate::graph::io`]; the store is format 02.
pub const STORE_MAGIC: &[u8; 8] = b"HPGNNG02";
pub const HEADER_BYTES: usize = 80;
pub const CHUNK_ENTRY_BYTES: usize = 24;
pub const MAX_NAME_BYTES: usize = 128;
/// Default edges per chunk for `hp-gnn graph pack` (64Ki edges = 256 KiB
/// per chunk — large enough to amortize seeks, small enough to stream).
pub const DEFAULT_CHUNK_EDGES: u64 = 64 * 1024;
/// Flags bit 0: a per-edge f32 value section follows the neighbor section.
pub const FLAG_VALUES: u64 = 1;

/// Parsed, validated header plus the derived section offsets.
#[derive(Debug, Clone)]
pub struct StoreMeta {
    pub num_vertices: usize,
    pub num_edges: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub graph_version: u64,
    pub chunk_edges: u64,
    pub num_chunks: usize,
    pub flags: u64,
    pub name: String,
    /// Byte offset of the chunk table.
    pub chunk_table_off: usize,
    /// Byte offset of the degree section.
    pub degree_off: usize,
    /// Byte offset of the (4-byte aligned) neighbor section.
    pub neigh_off: usize,
    /// Byte offset of the value section, when `flags` bit 0 is set.
    pub val_off: Option<usize>,
    pub file_len: usize,
}

/// One chunk-table entry: `nbytes` of neighbor data at `file_offset`,
/// covering edges `[edge_base, edge_base + nbytes/4)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkEntry {
    pub file_offset: u64,
    pub nbytes: u64,
    pub edge_base: u64,
}

/// What [`pack`] wrote — surfaced by the CLI verb and CI smoke.
#[derive(Debug, Clone, Copy)]
pub struct PackStats {
    pub num_vertices: usize,
    pub num_edges: usize,
    pub num_chunks: usize,
    pub bytes_written: u64,
}

fn u64_at(bytes: &[u8], off: usize) -> Option<u64> {
    let end = off.checked_add(8)?;
    let win = bytes.get(off..end)?;
    let mut b = [0u8; 8];
    b.copy_from_slice(win);
    Some(u64::from_le_bytes(b))
}

fn oversized(what: &str) -> anyhow::Error {
    anyhow::anyhow!("graph store header rejected: {what} overflows size arithmetic")
}

/// Round `n` up to a multiple of 8 (checked).
fn pad8(n: usize) -> Option<usize> {
    n.checked_add(7).map(|x| x & !7)
}

/// Parse and validate the fixed header + name from the file prefix.
/// `head` must contain at least the first `min(file_len, 80 + 136)` bytes;
/// `file_len` is the true on-disk length, checked *exactly* against the
/// section layout the header claims.
pub fn read_header(head: &[u8], file_len: usize) -> anyhow::Result<StoreMeta> {
    anyhow::ensure!(
        file_len >= HEADER_BYTES && head.len() >= HEADER_BYTES,
        "graph store rejected: {file_len} bytes is shorter than the {HEADER_BYTES}-byte header"
    );
    anyhow::ensure!(
        &head[..8] == STORE_MAGIC,
        "graph store rejected: bad magic {:?} (want {:?} — is this an \
         HPGNNG01 flat binary or a different file?)",
        &head[..8],
        STORE_MAGIC
    );
    let v64 = u64_at(head, 8).ok_or_else(|| oversized("|V|"))?;
    let e64 = u64_at(head, 16).ok_or_else(|| oversized("|E|"))?;
    let feat64 = u64_at(head, 24).ok_or_else(|| oversized("feat_dim"))?;
    let classes64 = u64_at(head, 32).ok_or_else(|| oversized("num_classes"))?;
    let graph_version = u64_at(head, 40).ok_or_else(|| oversized("graph_version"))?;
    let chunk_edges = u64_at(head, 48).ok_or_else(|| oversized("chunk_edges"))?;
    let chunks64 = u64_at(head, 56).ok_or_else(|| oversized("num_chunks"))?;
    let flags = u64_at(head, 64).ok_or_else(|| oversized("flags"))?;
    let name64 = u64_at(head, 72).ok_or_else(|| oversized("name_len"))?;

    let num_vertices = usize::try_from(v64).map_err(|_| oversized("|V|"))?;
    let num_edges = usize::try_from(e64).map_err(|_| oversized("|E|"))?;
    let feat_dim = usize::try_from(feat64).map_err(|_| oversized("feat_dim"))?;
    let num_classes = usize::try_from(classes64).map_err(|_| oversized("num_classes"))?;
    let num_chunks = usize::try_from(chunks64).map_err(|_| oversized("num_chunks"))?;
    let name_len = usize::try_from(name64).map_err(|_| oversized("name_len"))?;

    anyhow::ensure!(
        name_len <= MAX_NAME_BYTES,
        "graph store rejected: name_len {name_len} exceeds the {MAX_NAME_BYTES}-byte cap"
    );
    anyhow::ensure!(chunk_edges >= 1, "graph store rejected: chunk_edges must be >= 1");
    anyhow::ensure!(
        flags & !FLAG_VALUES == 0,
        "graph store rejected: unknown flags {flags:#x} (this reader understands {FLAG_VALUES:#x})"
    );
    // The chunk count is determined by (|E|, chunk_edges); a mismatch means
    // a corrupt or hostile header.
    let want_chunks64 = if e64 == 0 {
        0
    } else {
        e64.checked_sub(1)
            .and_then(|x| x.checked_div(chunk_edges))
            .and_then(|x| x.checked_add(1))
            .ok_or_else(|| oversized("num_chunks"))?
    };
    anyhow::ensure!(
        chunks64 == want_chunks64,
        "graph store rejected: num_chunks {chunks64} inconsistent with \
         |E|={e64} at {chunk_edges} edges/chunk (want {want_chunks64})"
    );

    // Section layout, every step checked: a hostile |V|/|E| must fail
    // here, not wrap and alias a small valid-looking layout.
    let name_padded = pad8(name_len).ok_or_else(|| oversized("name padding"))?;
    let chunk_table_off =
        HEADER_BYTES.checked_add(name_padded).ok_or_else(|| oversized("chunk table offset"))?;
    let chunk_table_bytes =
        num_chunks.checked_mul(CHUNK_ENTRY_BYTES).ok_or_else(|| oversized("chunk table"))?;
    let degree_off =
        chunk_table_off.checked_add(chunk_table_bytes).ok_or_else(|| oversized("degree offset"))?;
    let degree_bytes = num_vertices.checked_mul(4).ok_or_else(|| oversized("degree section"))?;
    let neigh_unaligned =
        degree_off.checked_add(degree_bytes).ok_or_else(|| oversized("neighbor offset"))?;
    let neigh_off = neigh_unaligned
        .checked_add(3)
        .map(|x| x & !3)
        .ok_or_else(|| oversized("neighbor alignment"))?;
    let neigh_bytes = num_edges.checked_mul(4).ok_or_else(|| oversized("neighbor section"))?;
    let neigh_end = neigh_off.checked_add(neigh_bytes).ok_or_else(|| oversized("neighbor end"))?;
    let (val_off, expected_len) = if flags & FLAG_VALUES != 0 {
        let val_bytes = num_edges.checked_mul(4).ok_or_else(|| oversized("value section"))?;
        let end = neigh_end.checked_add(val_bytes).ok_or_else(|| oversized("value end"))?;
        (Some(neigh_end), end)
    } else {
        (None, neigh_end)
    };
    anyhow::ensure!(
        file_len == expected_len,
        "graph store rejected: file is {file_len} bytes but the header \
         describes {expected_len} (truncated or trailing garbage)"
    );

    let name_end = HEADER_BYTES.checked_add(name_len).ok_or_else(|| oversized("name"))?;
    let name_bytes = head
        .get(HEADER_BYTES..name_end)
        .ok_or_else(|| anyhow::anyhow!("graph store rejected: name truncated"))?;
    let name = String::from_utf8(name_bytes.to_vec())
        .map_err(|_| anyhow::anyhow!("graph store rejected: name is not UTF-8"))?;

    Ok(StoreMeta {
        num_vertices,
        num_edges,
        feat_dim,
        num_classes,
        graph_version,
        chunk_edges,
        num_chunks,
        flags,
        name,
        chunk_table_off,
        degree_off,
        neigh_off,
        val_off,
        file_len,
    })
}

/// Parse the chunk table and verify it tiles the neighbor section exactly
/// — overlapping, out-of-bounds, or misplaced entries are rejected.
pub fn read_chunk_table(table: &[u8], meta: &StoreMeta) -> anyhow::Result<Vec<ChunkEntry>> {
    let want_bytes = meta
        .num_chunks
        .checked_mul(CHUNK_ENTRY_BYTES)
        .ok_or_else(|| oversized("chunk table"))?;
    anyhow::ensure!(
        table.len() == want_bytes,
        "graph store rejected: chunk table truncated ({} bytes, want {want_bytes})",
        table.len()
    );
    let mut entries = Vec::with_capacity(meta.num_chunks);
    let e64 = meta.num_edges as u64;
    let neigh_off64 = meta.neigh_off as u64;
    for i in 0..meta.num_chunks {
        let base = i.checked_mul(CHUNK_ENTRY_BYTES).ok_or_else(|| oversized("chunk entry"))?;
        let file_offset = u64_at(table, base).ok_or_else(|| oversized("chunk offset"))?;
        let nbytes = u64_at(table, base.checked_add(8).ok_or_else(|| oversized("chunk entry"))?)
            .ok_or_else(|| oversized("chunk nbytes"))?;
        let edge_base = u64_at(table, base.checked_add(16).ok_or_else(|| oversized("chunk entry"))?)
            .ok_or_else(|| oversized("chunk edge_base"))?;

        let want_base =
            (i as u64).checked_mul(meta.chunk_edges).ok_or_else(|| oversized("chunk edge_base"))?;
        anyhow::ensure!(
            edge_base == want_base,
            "graph store rejected: chunk {i} edge_base {edge_base} does not \
             tile the edge range (want {want_base})"
        );
        let span = meta.chunk_edges.min(e64.saturating_sub(want_base));
        let want_nbytes = span.checked_mul(4).ok_or_else(|| oversized("chunk span"))?;
        anyhow::ensure!(
            nbytes == want_nbytes,
            "graph store rejected: chunk {i} covers {nbytes} bytes, want \
             {want_nbytes} — chunks must not overlap or leave gaps"
        );
        let want_off = want_base
            .checked_mul(4)
            .and_then(|x| x.checked_add(neigh_off64))
            .ok_or_else(|| oversized("chunk offset"))?;
        anyhow::ensure!(
            file_offset == want_off,
            "graph store rejected: chunk {i} at file offset {file_offset} \
             overlaps or strays from the neighbor section (want {want_off})"
        );
        let end = file_offset.checked_add(nbytes).ok_or_else(|| oversized("chunk end"))?;
        anyhow::ensure!(
            end <= meta.file_len as u64,
            "graph store rejected: chunk {i} ends at byte {end}, past the \
             {}-byte file",
            meta.file_len
        );
        entries.push(ChunkEntry { file_offset, nbytes, edge_base });
    }
    Ok(entries)
}

/// Decode the degree section into a row-pointer array (`|V| + 1` entries).
/// The checked prefix sum must land exactly on `|E|`.
pub fn read_row_ptr(degrees: &[u8], meta: &StoreMeta) -> anyhow::Result<Vec<u64>> {
    let want_bytes = meta.num_vertices.checked_mul(4).ok_or_else(|| oversized("degree section"))?;
    anyhow::ensure!(
        degrees.len() == want_bytes,
        "graph store rejected: degree section truncated ({} bytes, want {want_bytes})",
        degrees.len()
    );
    let cap = meta.num_vertices.checked_add(1).ok_or_else(|| oversized("row_ptr"))?;
    let mut row_ptr = Vec::with_capacity(cap);
    row_ptr.push(0u64);
    let mut total: u64 = 0;
    for (v, win) in degrees.chunks_exact(4).enumerate() {
        let deg = u32::from_le_bytes([win[0], win[1], win[2], win[3]]) as u64;
        total = total.checked_add(deg).ok_or_else(|| {
            anyhow::anyhow!("graph store rejected: degree sum overflows at vertex {v}")
        })?;
        row_ptr.push(total);
    }
    anyhow::ensure!(
        total == meta.num_edges as u64,
        "graph store rejected: degrees sum to {total} edges but the header \
         claims {}",
        meta.num_edges
    );
    Ok(row_ptr)
}

fn put_u64(w: &mut impl Write, x: u64) -> std::io::Result<()> {
    w.write_all(&x.to_le_bytes())
}

/// Pack any [`GraphAccess`] into the `HPGNNG02` format at `path`.
///
/// Works off the trait surface so a [`super::GraphSnapshot`] (base store +
/// ingest delta) compacts through the same writer as an in-RAM
/// [`crate::graph::Graph`].  Neighbor lists are streamed vertex-major in
/// the order `neighbors` reports them, so a pack → open round trip
/// reproduces sampling bit-for-bit.
pub fn pack(
    g: &dyn GraphAccess,
    path: &Path,
    graph_version: u64,
    chunk_edges: u64,
) -> anyhow::Result<PackStats> {
    anyhow::ensure!(chunk_edges >= 1, "chunk_edges must be >= 1");
    let name = g.graph_name();
    anyhow::ensure!(
        name.len() <= MAX_NAME_BYTES,
        "graph name is {} bytes; the store format caps names at {MAX_NAME_BYTES}",
        name.len()
    );
    let num_vertices = g.num_vertices();
    let num_edges = g.num_edges();
    let e64 = num_edges as u64;
    let num_chunks = if e64 == 0 { 0 } else { ((e64 - 1) / chunk_edges) + 1 };

    let file = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("cannot create graph store {}: {e}", path.display()))?;
    let mut w = std::io::BufWriter::new(file);

    w.write_all(STORE_MAGIC)?;
    put_u64(&mut w, num_vertices as u64)?;
    put_u64(&mut w, e64)?;
    put_u64(&mut w, g.feat_dim() as u64)?;
    put_u64(&mut w, g.num_classes() as u64)?;
    put_u64(&mut w, graph_version)?;
    put_u64(&mut w, chunk_edges)?;
    put_u64(&mut w, num_chunks)?;
    put_u64(&mut w, 0)?; // flags: no value section (reserved for packed edge values)
    put_u64(&mut w, name.len() as u64)?;
    w.write_all(name.as_bytes())?;
    let name_padded = pad8(name.len()).ok_or_else(|| oversized("name padding"))?;
    w.write_all(&vec![0u8; name_padded - name.len()])?;

    // Section offsets mirror read_header's layout computation.
    let chunk_table_bytes = (num_chunks as usize)
        .checked_mul(CHUNK_ENTRY_BYTES)
        .ok_or_else(|| oversized("chunk table"))?;
    let degree_off = HEADER_BYTES
        .checked_add(name_padded)
        .and_then(|x| x.checked_add(chunk_table_bytes))
        .ok_or_else(|| oversized("degree offset"))?;
    let degree_bytes = num_vertices.checked_mul(4).ok_or_else(|| oversized("degree section"))?;
    let neigh_unaligned =
        degree_off.checked_add(degree_bytes).ok_or_else(|| oversized("neighbor offset"))?;
    let neigh_off =
        neigh_unaligned.checked_add(3).map(|x| x & !3).ok_or_else(|| oversized("alignment"))?;
    let pad = neigh_off - neigh_unaligned;

    for i in 0..num_chunks {
        let edge_base = i
            .checked_mul(chunk_edges)
            .ok_or_else(|| oversized("chunk edge_base"))?;
        let span = chunk_edges.min(e64 - edge_base);
        let nbytes = span.checked_mul(4).ok_or_else(|| oversized("chunk span"))?;
        let file_offset = edge_base
            .checked_mul(4)
            .and_then(|x| x.checked_add(neigh_off as u64))
            .ok_or_else(|| oversized("chunk offset"))?;
        put_u64(&mut w, file_offset)?;
        put_u64(&mut w, nbytes)?;
        put_u64(&mut w, edge_base)?;
    }

    for v in 0..num_vertices {
        let deg = g.degree(v as Vid);
        let deg32 = u32::try_from(deg).map_err(|_| {
            anyhow::anyhow!("vertex {v} has degree {deg}, beyond the format's u32 cap")
        })?;
        w.write_all(&deg32.to_le_bytes())?;
    }
    w.write_all(&vec![0u8; pad])?;

    let mut written_edges: u64 = 0;
    for v in 0..num_vertices {
        let neigh = g.neighbors(v as Vid);
        for &u in neigh.iter() {
            w.write_all(&u.to_le_bytes())?;
        }
        written_edges = written_edges
            .checked_add(neigh.len() as u64)
            .ok_or_else(|| oversized("edge count"))?;
    }
    anyhow::ensure!(
        written_edges == e64,
        "graph reported |E|={e64} but yielded {written_edges} neighbors"
    );
    w.flush()?;

    let expected_len = (neigh_off as u64)
        .checked_add(e64.checked_mul(4).ok_or_else(|| oversized("neighbor section"))?)
        .ok_or_else(|| oversized("file length"))?;
    Ok(PackStats {
        num_vertices,
        num_edges,
        num_chunks: num_chunks as usize,
        bytes_written: expected_len,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("hpgnn-format-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_graph() -> Graph {
        let mut g = Graph::from_edges(5, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 0), (4, 0)]);
        g.feat_dim = 8;
        g.num_classes = 3;
        g.name = "fixture".into();
        g
    }

    /// Pack the sample graph and return the raw bytes for mutation.
    fn packed_bytes() -> Vec<u8> {
        let path = tmp("mutate.g2");
        pack(&sample_graph(), &path, 0, 4).unwrap();
        std::fs::read(&path).unwrap()
    }

    fn header_of(bytes: &[u8]) -> anyhow::Result<StoreMeta> {
        read_header(bytes, bytes.len())
    }

    #[test]
    fn round_trip_header_and_sections() {
        let bytes = packed_bytes();
        let meta = header_of(&bytes).unwrap();
        assert_eq!(meta.num_vertices, 5);
        assert_eq!(meta.num_edges, 6);
        assert_eq!(meta.feat_dim, 8);
        assert_eq!(meta.num_classes, 3);
        assert_eq!(meta.name, "fixture");
        assert_eq!(meta.num_chunks, 2, "6 edges at 4/chunk");
        assert_eq!(meta.neigh_off % 4, 0, "neighbor section must be aligned");

        let table = &bytes[meta.chunk_table_off..meta.degree_off];
        let chunks = read_chunk_table(table, &meta).unwrap();
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].edge_base, 0);
        assert_eq!(chunks[0].nbytes, 16);
        assert_eq!(chunks[1].edge_base, 4);
        assert_eq!(chunks[1].nbytes, 8);

        let degrees = &bytes[meta.degree_off..meta.degree_off + 5 * 4];
        let row_ptr = read_row_ptr(degrees, &meta).unwrap();
        assert_eq!(row_ptr, vec![0, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn rejects_wrong_magic() {
        let mut bytes = packed_bytes();
        bytes[..8].copy_from_slice(b"HPGNNG01");
        let err = header_of(&bytes).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn rejects_truncated_file() {
        let bytes = packed_bytes();
        // Cut inside the chunk table.
        let meta = header_of(&bytes).unwrap();
        let cut = &bytes[..meta.chunk_table_off + 10];
        let err = read_header(cut, cut.len()).unwrap_err().to_string();
        assert!(err.contains("truncated") || err.contains("describes"), "{err}");
        // And a file shorter than the header itself.
        let err = read_header(&bytes[..40], 40).unwrap_err().to_string();
        assert!(err.contains("shorter than"), "{err}");
    }

    #[test]
    fn rejects_overflowing_header_counts() {
        for (v, e) in [(u64::MAX, 0u64), (0, u64::MAX), (u64::MAX, u64::MAX), (u64::MAX / 2, 8)] {
            let mut bytes = packed_bytes();
            bytes[8..16].copy_from_slice(&v.to_le_bytes());
            bytes[16..24].copy_from_slice(&e.to_le_bytes());
            let err = header_of(&bytes).unwrap_err().to_string();
            assert!(err.contains("rejected"), "V={v} E={e}: {err}");
        }
    }

    #[test]
    fn rejects_inconsistent_chunk_count() {
        let mut bytes = packed_bytes();
        bytes[56..64].copy_from_slice(&99u64.to_le_bytes());
        let err = header_of(&bytes).unwrap_err().to_string();
        assert!(err.contains("num_chunks"), "{err}");
    }

    #[test]
    fn rejects_unknown_flags_and_oversized_name() {
        let mut bytes = packed_bytes();
        bytes[64..72].copy_from_slice(&0xff00u64.to_le_bytes());
        assert!(header_of(&bytes).unwrap_err().to_string().contains("unknown flags"));

        let mut bytes = packed_bytes();
        bytes[72..80].copy_from_slice(&1000u64.to_le_bytes());
        assert!(header_of(&bytes).unwrap_err().to_string().contains("name_len"));
    }

    #[test]
    fn rejects_overlapping_chunk_offsets() {
        let bytes = packed_bytes();
        let meta = header_of(&bytes).unwrap();
        // Point chunk 1 back at chunk 0's bytes (overlap).
        let mut evil = bytes.clone();
        let e1 = meta.chunk_table_off + CHUNK_ENTRY_BYTES;
        let chunk0_off = u64_at(&bytes, meta.chunk_table_off).unwrap();
        evil[e1..e1 + 8].copy_from_slice(&chunk0_off.to_le_bytes());
        let table = &evil[meta.chunk_table_off..meta.degree_off];
        let err = read_chunk_table(table, &meta).unwrap_err().to_string();
        assert!(err.contains("overlaps") || err.contains("tile"), "{err}");
    }

    #[test]
    fn rejects_out_of_bounds_chunk_offsets() {
        let bytes = packed_bytes();
        let meta = header_of(&bytes).unwrap();
        let mut evil = bytes.clone();
        let e0 = meta.chunk_table_off;
        evil[e0..e0 + 8].copy_from_slice(&(meta.file_len as u64 + 4096).to_le_bytes());
        let table = &evil[meta.chunk_table_off..meta.degree_off];
        let err = read_chunk_table(table, &meta).unwrap_err().to_string();
        assert!(err.contains("overlaps") || err.contains("past"), "{err}");
    }

    #[test]
    fn rejects_degree_sum_mismatch() {
        let bytes = packed_bytes();
        let meta = header_of(&bytes).unwrap();
        let mut evil = bytes.clone();
        let d0 = meta.degree_off;
        evil[d0..d0 + 4].copy_from_slice(&100u32.to_le_bytes());
        let degrees = &evil[meta.degree_off..meta.degree_off + meta.num_vertices * 4];
        let err = read_row_ptr(degrees, &meta).unwrap_err().to_string();
        assert!(err.contains("sum to"), "{err}");
    }

    #[test]
    fn empty_graph_packs_and_parses() {
        let mut g = Graph::from_edges(3, &[]);
        g.name = "empty".into();
        let path = tmp("empty.g2");
        let stats = pack(&g, &path, 7, DEFAULT_CHUNK_EDGES).unwrap();
        assert_eq!(stats.num_chunks, 0);
        let bytes = std::fs::read(&path).unwrap();
        let meta = header_of(&bytes).unwrap();
        assert_eq!(meta.num_edges, 0);
        assert_eq!(meta.graph_version, 7);
        let row_ptr =
            read_row_ptr(&bytes[meta.degree_off..meta.degree_off + 12], &meta).unwrap();
        assert_eq!(row_ptr, vec![0, 0, 0, 0]);
    }
}
