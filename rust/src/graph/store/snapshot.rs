//! Versioned snapshots over a base graph: edge-stream ingest with an
//! in-memory delta overlay, and compaction back to a packed store.
//!
//! Concurrency contract: a [`GraphSnapshot`] is immutable once handed
//! out.  Samplers and the serving path pin one snapshot per batch, so an
//! ingest that produces version `v+1` can never change the neighborhoods
//! an in-flight batch at version `v` observes.  [`DynamicGraph`]
//! deliberately does **not** implement [`GraphAccess`] — callers must go
//! through [`DynamicGraph::snapshot`], which makes un-pinned access a
//! compile error rather than a race.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::graph::{Graph, GraphAccess, Vid};
use crate::util::sync::lock_unpoisoned;

use super::format::{self, PackStats};
use super::GraphStore;

/// An immutable view of the graph at one version: a shared base plus a
/// (possibly empty) sorted edge-delta overlay.
///
/// The delta maps source vertex → sorted insertion list; `neighbors`
/// merges it with the base adjacency, preserving ascending order with
/// duplicates kept — exactly what [`Graph::from_edges`] would produce had
/// the edges been present at construction, so compaction and overlay
/// reads agree bit-for-bit.
#[derive(Debug)]
pub struct GraphSnapshot {
    base: Arc<dyn GraphAccess>,
    delta: BTreeMap<Vid, Vec<Vid>>,
    delta_edges: usize,
    version: u64,
}

impl GraphSnapshot {
    fn fixed(base: Arc<dyn GraphAccess>) -> GraphSnapshot {
        let version = base.version();
        GraphSnapshot { base, delta: BTreeMap::new(), delta_edges: 0, version }
    }

    /// Edges in the overlay (0 once compacted).
    pub fn delta_edges(&self) -> usize {
        self.delta_edges
    }
}

/// Merge two ascending lists, keeping duplicates (multiset union).
fn merge_sorted(a: &[Vid], b: &[Vid]) -> Vec<Vid> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

impl GraphAccess for GraphSnapshot {
    fn num_vertices(&self) -> usize {
        self.base.num_vertices()
    }

    fn num_edges(&self) -> usize {
        self.base.num_edges() + self.delta_edges
    }

    fn feat_dim(&self) -> usize {
        self.base.feat_dim()
    }

    fn num_classes(&self) -> usize {
        self.base.num_classes()
    }

    fn graph_name(&self) -> &str {
        self.base.graph_name()
    }

    fn degree(&self, v: Vid) -> usize {
        let extra = self.delta.get(&v).map_or(0, Vec::len);
        self.base.degree(v) + extra
    }

    fn neighbors(&self, v: Vid) -> std::borrow::Cow<'_, [Vid]> {
        match self.delta.get(&v) {
            None => self.base.neighbors(v),
            Some(extra) => {
                std::borrow::Cow::Owned(merge_sorted(&self.base.neighbors(v), extra))
            }
        }
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn bytes_mapped(&self) -> u64 {
        self.base.bytes_mapped()
    }
}

/// A mutable handle over an evolving graph: the current snapshot plus the
/// ingest/compact operations that advance it.
///
/// Lock discipline: one leaf mutex guarding the current `Arc`; no other
/// lock is ever taken while it is held and no blocking call runs under
/// it, so it cannot participate in a lock-order cycle.
#[derive(Debug)]
pub struct DynamicGraph {
    current: Mutex<Arc<GraphSnapshot>>,
}

impl DynamicGraph {
    /// Wrap a static base (in-RAM graph or opened store) at its baked-in
    /// version with an empty delta.
    pub fn fixed(base: Arc<dyn GraphAccess>) -> Arc<DynamicGraph> {
        Arc::new(DynamicGraph { current: Mutex::new(Arc::new(GraphSnapshot::fixed(base))) })
    }

    pub fn from_graph(g: Graph) -> Arc<DynamicGraph> {
        DynamicGraph::fixed(Arc::new(g))
    }

    /// Pin the current snapshot.  Cheap (one Arc clone under a leaf
    /// lock); hold the result for the whole batch.
    pub fn snapshot(&self) -> Arc<GraphSnapshot> {
        Arc::clone(&lock_unpoisoned(&self.current))
    }

    /// Insert directed edges, producing the next snapshot version.
    /// Endpoints must name existing vertices (the store's feature space
    /// is sized at pack time; growing |V| requires a repack).  Returns
    /// the new version.  Rejected batches leave the graph untouched.
    pub fn ingest(&self, edges: &[(Vid, Vid)]) -> anyhow::Result<u64> {
        let mut guard = lock_unpoisoned(&self.current);
        let cur = Arc::clone(&guard);
        let n = cur.num_vertices();
        for (i, &(u, v)) in edges.iter().enumerate() {
            anyhow::ensure!(
                (u as usize) < n && (v as usize) < n,
                "ingest edge {i} = ({u}, {v}) is out of range (|V|={n}; \
                 repack to grow the vertex set)"
            );
        }
        let _sp = crate::obs::span_with("store", "ingest", || {
            vec![("edges", edges.len() as f64)]
        });
        let mut delta = cur.delta.clone();
        for &(u, v) in edges {
            let list = delta.entry(u).or_default();
            let pos = list.partition_point(|&x| x <= v);
            list.insert(pos, v);
        }
        let next = GraphSnapshot {
            base: Arc::clone(&cur.base),
            delta,
            delta_edges: cur.delta_edges + edges.len(),
            version: cur.version + 1,
        };
        let version = next.version;
        *guard = Arc::new(next);
        Ok(version)
    }

    /// Fold the current snapshot (base + delta) into a packed store at
    /// `path`, then swap the freshly opened store in as the new base —
    /// unless an ingest raced past us, in which case the file is still
    /// written but the in-memory graph keeps its newer state.  Returns
    /// the pack stats and whether the swap happened.
    pub fn compact_to(&self, path: &Path) -> anyhow::Result<(PackStats, bool)> {
        let pinned = self.snapshot();
        self.compact_snapshot_to(&pinned, path)
    }

    /// Compact a specific pinned snapshot.  The on-disk pack always
    /// happens; the in-memory swap lands only if `pinned` is still the
    /// current version once packing finishes (i.e. no ingest raced past).
    pub fn compact_snapshot_to(
        &self,
        pinned: &Arc<GraphSnapshot>,
        path: &Path,
    ) -> anyhow::Result<(PackStats, bool)> {
        // Pack outside the lock: compaction is long, ingest must not stall.
        let stats =
            format::pack(pinned.as_ref(), path, pinned.version, format::DEFAULT_CHUNK_EDGES)?;
        let store: Arc<dyn GraphAccess> = Arc::new(GraphStore::open(path)?);
        let compacted = Arc::new(GraphSnapshot::fixed(store));
        let mut guard = lock_unpoisoned(&self.current);
        let swapped = guard.version == pinned.version;
        if swapped {
            *guard = compacted;
        }
        Ok((stats, swapped))
    }

    // Delegating conveniences for call sites that only need scalars and
    // would otherwise pin a snapshot per field read.

    pub fn version(&self) -> u64 {
        self.snapshot().version
    }

    pub fn num_vertices(&self) -> usize {
        self.snapshot().num_vertices()
    }

    pub fn num_edges(&self) -> usize {
        self.snapshot().num_edges()
    }

    pub fn feat_dim(&self) -> usize {
        self.snapshot().feat_dim()
    }

    pub fn num_classes(&self) -> usize {
        self.snapshot().num_classes()
    }

    pub fn name(&self) -> String {
        self.snapshot().graph_name().to_string()
    }

    pub fn bytes_mapped(&self) -> u64 {
        self.snapshot().bytes_mapped()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Graph {
        let mut g = Graph::from_edges(5, &[(0, 1), (0, 3), (1, 2), (3, 4), (4, 0)]);
        g.feat_dim = 4;
        g.num_classes = 2;
        g.name = "dyn-fixture".into();
        g
    }

    #[test]
    fn fixed_snapshot_is_version_zero_and_transparent() {
        let dg = DynamicGraph::from_graph(fixture());
        let snap = dg.snapshot();
        assert_eq!(snap.version(), 0);
        assert_eq!(snap.num_edges(), 5);
        assert_eq!(&*snap.neighbors(0), &[1, 3]);
        assert_eq!(snap.delta_edges(), 0);
    }

    #[test]
    fn ingest_bumps_version_and_merges_sorted() {
        let dg = DynamicGraph::from_graph(fixture());
        let v1 = dg.ingest(&[(0, 2), (0, 1), (2, 4)]).unwrap();
        assert_eq!(v1, 1);
        let snap = dg.snapshot();
        // Base [1, 3] + inserts [1, 2], duplicates kept, ascending.
        assert_eq!(&*snap.neighbors(0), &[1, 1, 2, 3]);
        assert_eq!(&*snap.neighbors(2), &[4]);
        assert_eq!(snap.num_edges(), 8);
        assert_eq!(snap.degree(0), 4);
        // Matches what from_edges would have produced outright.
        let rebuilt = Graph::from_edges(
            5,
            &[(0, 1), (0, 3), (1, 2), (3, 4), (4, 0), (0, 2), (0, 1), (2, 4)],
        );
        for v in 0..5 {
            assert_eq!(&*snap.neighbors(v), rebuilt.neighbors(v), "v={v}");
        }
    }

    #[test]
    fn pinned_snapshot_is_isolated_from_later_ingest() {
        let dg = DynamicGraph::from_graph(fixture());
        let pinned = dg.snapshot();
        dg.ingest(&[(1, 4)]).unwrap();
        assert_eq!(pinned.version(), 0);
        assert_eq!(&*pinned.neighbors(1), &[2], "pinned view must not move");
        assert_eq!(&*dg.snapshot().neighbors(1), &[2, 4]);
        assert_eq!(dg.version(), 1);
    }

    #[test]
    fn ingest_rejects_out_of_range_endpoints() {
        let dg = DynamicGraph::from_graph(fixture());
        let err = dg.ingest(&[(0, 99)]).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        assert_eq!(dg.version(), 0, "failed ingest must not bump the version");
    }

    #[test]
    fn compact_folds_delta_to_disk_and_keeps_version() {
        let dir = std::env::temp_dir().join(format!("hpgnn-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("compact.g2");

        let dg = DynamicGraph::from_graph(fixture());
        dg.ingest(&[(0, 2), (2, 3)]).unwrap();
        let before = dg.snapshot();
        let (stats, swapped) = dg.compact_to(&path).unwrap();
        assert!(swapped);
        assert_eq!(stats.num_edges, 7);

        let after = dg.snapshot();
        assert_eq!(after.version(), 1, "compaction preserves the version");
        assert_eq!(after.delta_edges(), 0, "delta folded into the base");
        for v in 0..5 {
            assert_eq!(&*after.neighbors(v), &*before.neighbors(v), "v={v}");
        }
        assert_eq!(after.graph_name(), "dyn-fixture");
        assert_eq!(after.feat_dim(), 4);
    }

    #[test]
    fn compact_skips_swap_when_ingest_races() {
        let dir = std::env::temp_dir().join(format!("hpgnn-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("race.g2");

        let dg = DynamicGraph::from_graph(fixture());
        let pinned = dg.snapshot();
        // The race: an ingest lands between pinning and compacting.  The
        // pack still hits disk, but the in-memory swap must be refused —
        // otherwise the newer edge would be silently dropped.
        dg.ingest(&[(1, 0)]).unwrap();
        let (stats, swapped) = dg.compact_snapshot_to(&pinned, &path).unwrap();
        assert!(!swapped, "stale compaction must not clobber a newer version");
        assert_eq!(stats.num_edges, 5, "the pack reflects the pinned (stale) view");
        assert_eq!(dg.version(), 1);
        assert_eq!(&*dg.snapshot().neighbors(1), &[0, 2], "ingested edge survives");

        // A fresh compact_to (which pins the current version) does swap.
        let (_stats, swapped) = dg.compact_to(&path).unwrap();
        assert!(swapped);
        assert_eq!(dg.version(), 1);
        assert_eq!(dg.snapshot().delta_edges(), 0);
    }
}
