//! Graph substrate: CSR storage, synthetic generators, dataset registry.
//!
//! The paper stores the structural information (V, E) in host memory for
//! the CPU sampler and the feature matrix X in FPGA local DDR (Fig. 3).
//! [`Graph`] is the host-side structure; feature placement across DDR
//! channels is modeled by [`partition`].

pub mod datasets;
pub mod generator;
pub mod io;
pub mod partition;
pub mod store;

use std::borrow::Cow;

use crate::util::rng::Pcg64;

/// Vertex id. 32 bits covers the paper's largest dataset (AmazonProducts,
/// 1.6M vertices) with room to spare and halves sampler memory traffic.
pub type Vid = u32;

/// The neighbor-access surface samplers and inference consume — what both
/// the in-RAM [`Graph`] and the out-of-core [`store::GraphStore`] (plus
/// its [`store::GraphSnapshot`] overlay) provide.
///
/// The default-method formulas (`gcn_norm`, `avg_degree`) are verbatim
/// copies of [`Graph`]'s inherent ones, so a batch sampled through a
/// trait object is bit-identical to one sampled from the concrete graph:
/// the determinism contract (loss curve as a pure function of `(seed,
/// step)`) holds across backings.
///
/// `neighbors` returns [`Cow`] because the mmap-backed store can borrow
/// straight from the mapping while the pread fallback and the snapshot
/// overlay's merged adjacency must own their buffers.
pub trait GraphAccess: Send + Sync + std::fmt::Debug {
    fn num_vertices(&self) -> usize;
    fn num_edges(&self) -> usize;
    /// Input feature dimension (features are synthesized on demand).
    fn feat_dim(&self) -> usize;
    fn num_classes(&self) -> usize;
    /// Human-readable graph name (checkpoint fingerprints embed it).
    fn graph_name(&self) -> &str;
    fn degree(&self, v: Vid) -> usize;
    /// Sorted out-neighbors of `v` (ascending, duplicates kept) — the
    /// same order [`Graph::from_edges`] produces.
    fn neighbors(&self, v: Vid) -> Cow<'_, [Vid]>;

    /// Monotone snapshot version: 0 for static graphs, bumped by every
    /// edge-stream ingest on a dynamic graph.
    fn version(&self) -> u64 {
        0
    }

    /// Bytes of backing file currently mapped (out-of-core stores only).
    fn bytes_mapped(&self) -> u64 {
        0
    }

    /// Average degree (same formula as [`Graph::avg_degree`]).
    fn avg_degree(&self) -> f64 {
        self.num_edges() as f64 / self.num_vertices().max(1) as f64
    }

    /// GCN symmetric normalization (same formula as [`Graph::gcn_norm`];
    /// bit-identical because both go through the `f64` sqrt).
    fn gcn_norm(&self, u: Vid, v: Vid) -> f32 {
        let du = (self.degree(u) + 1) as f64;
        let dv = (self.degree(v) + 1) as f64;
        (1.0 / (du * dv).sqrt()) as f32
    }
}

impl GraphAccess for Graph {
    fn num_vertices(&self) -> usize {
        Graph::num_vertices(self)
    }

    fn num_edges(&self) -> usize {
        Graph::num_edges(self)
    }

    fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn graph_name(&self) -> &str {
        &self.name
    }

    fn degree(&self, v: Vid) -> usize {
        Graph::degree(self, v)
    }

    fn neighbors(&self, v: Vid) -> Cow<'_, [Vid]> {
        Cow::Borrowed(Graph::neighbors(self, v))
    }
}

/// Compressed-sparse-row graph with out-neighbor adjacency.
///
/// Edges are directed; undirected datasets store both directions.
/// `adj[row_ptr[v]..row_ptr[v+1]]` are the neighbors of `v`.
#[derive(Debug, Clone)]
pub struct Graph {
    pub row_ptr: Vec<usize>,
    pub adj: Vec<Vid>,
    /// Input feature dimension (features themselves are synthesized on
    /// demand — see `datasets::synth_features`).
    pub feat_dim: usize,
    pub num_classes: usize,
    pub name: String,
}

impl Graph {
    /// Build CSR from an edge list (duplicates kept, self loops kept —
    /// samplers and normalization decide policy).
    pub fn from_edges(num_vertices: usize, edges: &[(Vid, Vid)]) -> Graph {
        let mut deg = vec![0usize; num_vertices];
        for &(u, _) in edges {
            assert!((u as usize) < num_vertices, "edge source {u} out of range");
            deg[u as usize] += 1;
        }
        let mut row_ptr = vec![0usize; num_vertices + 1];
        for v in 0..num_vertices {
            row_ptr[v + 1] = row_ptr[v] + deg[v];
        }
        let mut adj = vec![0 as Vid; edges.len()];
        let mut cursor = row_ptr.clone();
        for &(u, v) in edges {
            assert!((v as usize) < num_vertices, "edge target {v} out of range");
            adj[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
        }
        // Sorted adjacency gives deterministic sampling + faster locality.
        for v in 0..num_vertices {
            adj[row_ptr[v]..row_ptr[v + 1]].sort_unstable();
        }
        Graph { row_ptr, adj, feat_dim: 0, num_classes: 0, name: String::new() }
    }

    pub fn num_vertices(&self) -> usize {
        self.row_ptr.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.adj.len()
    }

    pub fn degree(&self, v: Vid) -> usize {
        self.row_ptr[v as usize + 1] - self.row_ptr[v as usize]
    }

    pub fn neighbors(&self, v: Vid) -> &[Vid] {
        &self.adj[self.row_ptr[v as usize]..self.row_ptr[v as usize + 1]]
    }

    /// Uniformly sample one neighbor of `v`; None if isolated.
    pub fn sample_neighbor(&self, v: Vid, rng: &mut Pcg64) -> Option<Vid> {
        let n = self.neighbors(v);
        if n.is_empty() {
            None
        } else {
            Some(n[rng.index(n.len())])
        }
    }

    /// Average degree.
    pub fn avg_degree(&self) -> f64 {
        self.num_edges() as f64 / self.num_vertices().max(1) as f64
    }

    /// GCN symmetric normalization 1/sqrt(D(u) D(v)) for an edge (u, v),
    /// degrees counted with the self loop (A + I convention, Eq. 1).
    pub fn gcn_norm(&self, u: Vid, v: Vid) -> f32 {
        let du = (self.degree(u) + 1) as f64;
        let dv = (self.degree(v) + 1) as f64;
        (1.0 / (du * dv).sqrt()) as f32
    }

    /// Structural validation (used by tests and after deserialization).
    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.num_vertices();
        anyhow::ensure!(self.row_ptr[0] == 0, "row_ptr must start at 0");
        for v in 0..n {
            anyhow::ensure!(
                self.row_ptr[v] <= self.row_ptr[v + 1],
                "row_ptr not monotone at {v}"
            );
        }
        anyhow::ensure!(
            *self.row_ptr.last().unwrap() == self.adj.len(),
            "row_ptr tail {} != adj len {}",
            self.row_ptr.last().unwrap(),
            self.adj.len()
        );
        anyhow::ensure!(
            self.adj.iter().all(|&v| (v as usize) < n),
            "adjacency id out of range"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0 -> {1, 2}, 1 -> {3}, 2 -> {3}, 3 -> {0}
        Graph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 0)])
    }

    #[test]
    fn csr_construction() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[3]);
        assert_eq!(g.neighbors(3), &[0]);
        assert_eq!(g.degree(2), 1);
        g.validate().unwrap();
    }

    #[test]
    fn unsorted_input_sorted_adjacency() {
        let g = Graph::from_edges(3, &[(0, 2), (0, 1), (0, 0)]);
        assert_eq!(g.neighbors(0), &[0, 1, 2]);
    }

    #[test]
    fn isolated_vertex() {
        let g = Graph::from_edges(3, &[(0, 1)]);
        assert_eq!(g.degree(2), 0);
        assert!(g.neighbors(2).is_empty());
        let mut rng = Pcg64::seed_from_u64(0);
        assert_eq!(g.sample_neighbor(2, &mut rng), None);
    }

    #[test]
    fn sample_neighbor_uniform() {
        let g = diamond();
        let mut rng = Pcg64::seed_from_u64(1);
        let mut counts = [0usize; 2];
        for _ in 0..10_000 {
            match g.sample_neighbor(0, &mut rng) {
                Some(1) => counts[0] += 1,
                Some(2) => counts[1] += 1,
                other => panic!("unexpected neighbor {other:?}"),
            }
        }
        assert!(counts[0] > 4_500 && counts[1] > 4_500, "{counts:?}");
    }

    #[test]
    fn gcn_norm_symmetric_formula() {
        let g = diamond();
        // deg(0)=2, deg(1)=1; with self loops 3 and 2.
        let want = 1.0 / (3.0f32 * 2.0).sqrt();
        assert!((g.gcn_norm(0, 1) - want).abs() < 1e-6);
        assert_eq!(g.gcn_norm(0, 1), g.gcn_norm(1, 0));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range_edges() {
        Graph::from_edges(2, &[(0, 5)]);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut g = diamond();
        g.adj[0] = 99;
        assert!(g.validate().is_err());
    }
}
