//! Edge-list I/O: text (one `src dst` pair per line, `#` comments) and a
//! compact binary format for larger graphs.  `LoadInputGraph()` in the
//! paper's API (Table 1) maps here.

use std::io::{BufRead, BufWriter, Read, Write};
use std::path::Path;

use super::{Graph, Vid};

const BIN_MAGIC: &[u8; 8] = b"HPGNNG01";

/// Load a whitespace-separated edge list. Vertex count is
/// `max id + 1` unless a `# vertices: N` header is present.
pub fn load_edge_list(path: &Path) -> anyhow::Result<Graph> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    let mut declared_vertices: Option<usize> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(rest) = trimmed.strip_prefix('#') {
            if let Some(v) = rest.trim().strip_prefix("vertices:") {
                declared_vertices = Some(v.trim().parse()?);
            }
            continue;
        }
        let mut it = trimmed.split_whitespace();
        let u: Vid = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing src", lineno + 1))?
            .parse()?;
        let v: Vid = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing dst", lineno + 1))?
            .parse()?;
        edges.push((u, v));
    }
    let max_id = edges.iter().map(|&(u, v)| u.max(v)).max().unwrap_or(0) as usize;
    let n = declared_vertices.unwrap_or(max_id + 1).max(max_id + 1);
    let g = Graph::from_edges(n, &edges);
    g.validate()?;
    Ok(g)
}

/// Save as text edge list with a vertex-count header.
pub fn save_edge_list(g: &Graph, path: &Path) -> anyhow::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# vertices: {}", g.num_vertices())?;
    for v in 0..g.num_vertices() {
        for &u in g.neighbors(v as Vid) {
            writeln!(w, "{v} {u}")?;
        }
    }
    Ok(())
}

/// Save in the compact binary format (u64 counts, u32 ids, little endian).
pub fn save_binary(g: &Graph, path: &Path) -> anyhow::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    w.write_all(&(g.feat_dim as u64).to_le_bytes())?;
    w.write_all(&(g.num_classes as u64).to_le_bytes())?;
    for &p in &g.row_ptr {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &v in &g.adj {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load the binary format written by [`save_binary`].
pub fn load_binary(path: &Path) -> anyhow::Result<Graph> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    anyhow::ensure!(bytes.len() >= 40, "file too short");
    anyhow::ensure!(&bytes[..8] == BIN_MAGIC, "bad magic (not an hp-gnn graph)");
    let mut off = 8usize;
    let mut read_u64 = |bytes: &[u8]| -> anyhow::Result<u64> {
        anyhow::ensure!(off + 8 <= bytes.len(), "truncated header");
        let v = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
        off += 8;
        Ok(v)
    };
    let n = read_u64(&bytes)? as usize;
    let e = read_u64(&bytes)? as usize;
    let feat_dim = read_u64(&bytes)? as usize;
    let num_classes = read_u64(&bytes)? as usize;
    let need = off + (n + 1) * 8 + e * 4;
    anyhow::ensure!(bytes.len() == need, "size mismatch: have {}, want {need}", bytes.len());
    let mut row_ptr = Vec::with_capacity(n + 1);
    for i in 0..=n {
        let start = off + i * 8;
        row_ptr.push(u64::from_le_bytes(bytes[start..start + 8].try_into().unwrap()) as usize);
    }
    let adj_off = off + (n + 1) * 8;
    let mut adj = Vec::with_capacity(e);
    for i in 0..e {
        let start = adj_off + i * 4;
        adj.push(u32::from_le_bytes(bytes[start..start + 4].try_into().unwrap()));
    }
    let g = Graph { row_ptr, adj, feat_dim, num_classes, name: String::new() };
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("hpgnn-io-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn text_round_trip() {
        let g = generator::uniform(64, 300, false, 1);
        let path = tmpdir().join("g.txt");
        save_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices(), g2.num_vertices());
        assert_eq!(g.adj, g2.adj);
        assert_eq!(g.row_ptr, g2.row_ptr);
    }

    #[test]
    fn binary_round_trip_preserves_metadata() {
        let mut g = generator::rmat(128, 1000, Default::default(), 2);
        g.feat_dim = 500;
        g.num_classes = 7;
        let path = tmpdir().join("g.bin");
        save_binary(&g, &path).unwrap();
        let g2 = load_binary(&path).unwrap();
        assert_eq!(g.adj, g2.adj);
        assert_eq!(g2.feat_dim, 500);
        assert_eq!(g2.num_classes, 7);
    }

    #[test]
    fn text_parses_comments_and_header() {
        let path = tmpdir().join("c.txt");
        std::fs::write(&path, "# vertices: 10\n# comment\n0 1\n\n2 3\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices(), 10);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn binary_rejects_corruption() {
        let g = generator::uniform(32, 100, false, 3);
        let path = tmpdir().join("bad.bin");
        save_binary(&g, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 3);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_binary(&path).is_err());
        std::fs::write(&path, b"NOTMAGIC plus").unwrap();
        assert!(load_binary(&path).is_err());
    }

    #[test]
    fn text_rejects_garbage() {
        let path = tmpdir().join("garb.txt");
        std::fs::write(&path, "0 x\n").unwrap();
        assert!(load_edge_list(&path).is_err());
    }
}
