//! Edge-list I/O: text (one `src dst` pair per line, `#` comments) and a
//! compact binary format for larger graphs.  `LoadInputGraph()` in the
//! paper's API (Table 1) maps here.

use std::io::{BufRead, BufWriter, Read, Write};
use std::path::Path;

use super::{Graph, Vid};

const BIN_MAGIC: &[u8; 8] = b"HPGNNG01";

/// Load a whitespace-separated edge list. Vertex count is
/// `max id + 1` unless a `# vertices: N` header is present; a header
/// smaller than what the edges reference is rejected (naming the
/// offending edge), never silently widened.  An empty edge list with no
/// header is the empty graph.
pub fn load_edge_list(path: &Path) -> anyhow::Result<Graph> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    let mut declared_vertices: Option<usize> = None;
    // The edge carrying the largest endpoint id, with its line number —
    // what the error names when a declared header is too small.
    let mut max_edge: Option<(Vid, Vid, usize)> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(rest) = trimmed.strip_prefix('#') {
            if let Some(v) = rest.trim().strip_prefix("vertices:") {
                declared_vertices = Some(v.trim().parse()?);
            }
            continue;
        }
        let mut it = trimmed.split_whitespace();
        let u: Vid = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing src", lineno + 1))?
            .parse()?;
        let v: Vid = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing dst", lineno + 1))?
            .parse()?;
        match max_edge {
            Some((mu, mv, _)) if mu.max(mv) >= u.max(v) => {}
            _ => max_edge = Some((u, v, lineno + 1)),
        }
        edges.push((u, v));
    }
    let n = match (declared_vertices, max_edge) {
        (Some(n), Some((u, v, line))) => {
            let max_id = u.max(v) as usize;
            anyhow::ensure!(
                max_id < n,
                "line {line}: edge `{u} {v}` references vertex {max_id} but \
                 the `# vertices:` header declares only {n}"
            );
            n
        }
        (Some(n), None) => n,
        (None, Some((u, v, _))) => u.max(v) as usize + 1,
        // No edges, no header: the empty graph (not a phantom vertex 0).
        (None, None) => 0,
    };
    let g = Graph::from_edges(n, &edges);
    g.validate()?;
    Ok(g)
}

/// Save as text edge list with a vertex-count header.
pub fn save_edge_list(g: &Graph, path: &Path) -> anyhow::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# vertices: {}", g.num_vertices())?;
    for v in 0..g.num_vertices() {
        for &u in g.neighbors(v as Vid) {
            writeln!(w, "{v} {u}")?;
        }
    }
    Ok(())
}

/// Save in the compact binary format (u64 counts, u32 ids, little endian).
pub fn save_binary(g: &Graph, path: &Path) -> anyhow::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    w.write_all(&(g.feat_dim as u64).to_le_bytes())?;
    w.write_all(&(g.num_classes as u64).to_le_bytes())?;
    for &p in &g.row_ptr {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &v in &g.adj {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load the binary format written by [`save_binary`].
///
/// All size arithmetic is checked: an adversarial header whose counts
/// would wrap the expected-size computation (and so slip past the length
/// check into a panic or a huge allocation) is rejected up front, the
/// same hardening the `HPGNNS01` checkpoint loader applies.
pub fn load_binary(path: &Path) -> anyhow::Result<Graph> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    anyhow::ensure!(bytes.len() >= 40, "file too short");
    anyhow::ensure!(&bytes[..8] == BIN_MAGIC, "bad magic (not an hp-gnn graph)");
    let mut off = 8usize;
    let mut read_u64 = |bytes: &[u8]| -> anyhow::Result<u64> {
        anyhow::ensure!(off + 8 <= bytes.len(), "truncated header");
        let v = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
        off += 8;
        Ok(v)
    };
    let n64 = read_u64(&bytes)?;
    let e64 = read_u64(&bytes)?;
    let feat_dim = read_u64(&bytes)? as usize;
    let num_classes = read_u64(&bytes)? as usize;
    let oversized = || anyhow::anyhow!("header counts overflow (|V|={n64}, |E|={e64})");
    let n = usize::try_from(n64).map_err(|_| oversized())?;
    let e = usize::try_from(e64).map_err(|_| oversized())?;
    let row_bytes = n
        .checked_add(1)
        .and_then(|r| r.checked_mul(8))
        .ok_or_else(oversized)?;
    let need = e
        .checked_mul(4)
        .and_then(|adj| adj.checked_add(row_bytes))
        .and_then(|body| body.checked_add(off))
        .ok_or_else(oversized)?;
    anyhow::ensure!(bytes.len() == need, "size mismatch: have {}, want {need}", bytes.len());
    let row_ptr: Vec<usize> = bytes[off..off + row_bytes]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect();
    let adj: Vec<Vid> = bytes[off + row_bytes..]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let g = Graph { row_ptr, adj, feat_dim, num_classes, name: String::new() };
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("hpgnn-io-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn text_round_trip() {
        let g = generator::uniform(64, 300, false, 1);
        let path = tmpdir().join("g.txt");
        save_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices(), g2.num_vertices());
        assert_eq!(g.adj, g2.adj);
        assert_eq!(g.row_ptr, g2.row_ptr);
    }

    #[test]
    fn binary_round_trip_preserves_metadata() {
        let mut g = generator::rmat(128, 1000, Default::default(), 2);
        g.feat_dim = 500;
        g.num_classes = 7;
        let path = tmpdir().join("g.bin");
        save_binary(&g, &path).unwrap();
        let g2 = load_binary(&path).unwrap();
        assert_eq!(g.adj, g2.adj);
        assert_eq!(g2.feat_dim, 500);
        assert_eq!(g2.num_classes, 7);
    }

    #[test]
    fn text_parses_comments_and_header() {
        let path = tmpdir().join("c.txt");
        std::fs::write(&path, "# vertices: 10\n# comment\n0 1\n\n2 3\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices(), 10);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn binary_rejects_corruption() {
        let g = generator::uniform(32, 100, false, 3);
        let path = tmpdir().join("bad.bin");
        save_binary(&g, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 3);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_binary(&path).is_err());
        std::fs::write(&path, b"NOTMAGIC plus").unwrap();
        assert!(load_binary(&path).is_err());
    }

    #[test]
    fn text_rejects_garbage() {
        let path = tmpdir().join("garb.txt");
        std::fs::write(&path, "0 x\n").unwrap();
        assert!(load_edge_list(&path).is_err());
    }

    #[test]
    fn text_rejects_undersized_header_naming_the_edge() {
        let path = tmpdir().join("undersized.txt");
        std::fs::write(&path, "# vertices: 3\n0 1\n2 9\n1 0\n").unwrap();
        let err = load_edge_list(&path).unwrap_err().to_string();
        assert!(err.contains("2 9"), "{err}");
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("9") && err.contains("3"), "{err}");
    }

    #[test]
    fn text_empty_edge_list_is_the_empty_graph() {
        let path = tmpdir().join("empty.txt");
        std::fs::write(&path, "# just a comment\n\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices(), 0, "no phantom vertex");
        assert_eq!(g.num_edges(), 0);

        // With a header, the declared isolated vertices survive.
        let path = tmpdir().join("empty-header.txt");
        std::fs::write(&path, "# vertices: 5\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn binary_rejects_overflowing_header_counts() {
        // Adversarial header: |V| = u64::MAX would wrap `(n + 1) * 8` in
        // unchecked arithmetic and slip past the size check.
        for (n, e) in [
            (u64::MAX, 0u64),
            (u64::MAX / 8, 0),
            (0, u64::MAX),
            (u64::MAX, u64::MAX),
        ] {
            let mut bytes = Vec::new();
            bytes.extend_from_slice(BIN_MAGIC);
            bytes.extend_from_slice(&n.to_le_bytes());
            bytes.extend_from_slice(&e.to_le_bytes());
            bytes.extend_from_slice(&16u64.to_le_bytes()); // feat_dim
            bytes.extend_from_slice(&4u64.to_le_bytes()); // num_classes
            bytes.extend_from_slice(&[0u8; 8]); // some body bytes
            let path = tmpdir().join("overflow.bin");
            std::fs::write(&path, &bytes).unwrap();
            let err = load_binary(&path).unwrap_err().to_string();
            assert!(
                err.contains("overflow") || err.contains("size mismatch"),
                "|V|={n} |E|={e}: {err}"
            );
        }
    }
}
