//! User-program parser: the JSON analog of the paper's Listing 1.
//!
//! A user program is a small JSON document:
//!
//! ```json
//! {
//!   "platform": "xilinx-U250",
//!   "model": {"computation": "SAGE", "hidden": [256]},
//!   "sampler": {"type": "NeighborSampler", "budgets": [10, 25], "targets": 1024},
//!   "graph": {"dataset": "FL", "scale": 0.05, "seed": 1},
//!   "training": {"steps": 100, "lr": 0.05}
//! }
//! ```
//!
//! `parse_program` turns it into an [`HpGnn`] builder plus training
//! parameters; the `hp-gnn run` CLI subcommand executes it end to end.

use super::{HpGnn, SamplerSpec};
use crate::util::json::Json;

/// Training-phase parameters of a user program.
#[derive(Debug, Clone, Copy)]
pub struct TrainingParams {
    pub steps: usize,
    pub lr: f32,
    pub simulate: bool,
}

/// Parse a user program into a ready builder + training params.
pub fn parse_program(text: &str) -> anyhow::Result<(HpGnn, TrainingParams)> {
    let doc = Json::parse(text)?;

    let mut builder = HpGnn::init();

    // Platform.
    match doc.get("platform")? {
        Json::Str(board) => builder = builder.platform_board(board)?,
        other => anyhow::bail!("platform must be a board name string, got {other:?}"),
    }

    // Model.
    let model = doc.get("model")?;
    builder = builder.gnn_computation(model.get("computation")?.as_str()?)?;
    builder = builder.gnn_parameters(model.get("hidden")?.usize_list()?);

    // Sampler.
    let sampler = doc.get("sampler")?;
    let spec = match sampler.get("type")?.as_str()? {
        "NeighborSampler" => SamplerSpec::Neighbor {
            targets: sampler.get("targets")?.as_usize()?,
            budgets: sampler.get("budgets")?.usize_list()?,
        },
        "SubgraphSampler" => SamplerSpec::Subgraph {
            budget: sampler.get("budget")?.as_usize()?,
            layers: sampler.get("layers")?.as_usize()?,
        },
        "LayerwiseSampler" => SamplerSpec::Layerwise {
            targets: sampler.get("targets")?.as_usize()?,
            sizes: sampler.get("sizes")?.usize_list()?,
        },
        other => anyhow::bail!(
            "unknown sampler {other:?} (NeighborSampler|SubgraphSampler|LayerwiseSampler)"
        ),
    };
    builder = builder.sampler(spec);

    // Graph.
    let graph = doc.get("graph")?;
    let seed = graph.opt("seed").map(|j| j.as_usize()).transpose()?.unwrap_or(1) as u64;
    if let Some(ds) = graph.opt("dataset") {
        let scale = graph.opt("scale").map(|j| j.as_f64()).transpose()?.unwrap_or(1.0);
        builder = builder.load_dataset(ds.as_str()?, scale, seed)?;
    } else if let Some(path) = graph.opt("edge_list") {
        let mut g = crate::graph::io::load_edge_list(std::path::Path::new(path.as_str()?))?;
        g.feat_dim = graph.get("feat_dim")?.as_usize()?;
        g.num_classes = graph.get("num_classes")?.as_usize()?;
        builder = builder.load_input_graph(g);
    } else {
        anyhow::bail!("graph needs either \"dataset\" or \"edge_list\"");
    }
    builder = builder.seed(seed);

    // Training.
    let training = doc.get("training")?;
    let params = TrainingParams {
        steps: training.get("steps")?.as_usize()?,
        lr: training.get("lr")?.as_f64()? as f32,
        simulate: training
            .opt("simulate")
            .map(|j| j.as_bool())
            .transpose()?
            .unwrap_or(false),
    };

    Ok((builder, params))
}

#[cfg(test)]
mod tests {
    use super::*;

    const PROGRAM: &str = r#"{
      "platform": "xilinx-U250",
      "model": {"computation": "GCN", "hidden": [8]},
      "sampler": {"type": "NeighborSampler", "budgets": [5, 3], "targets": 4},
      "graph": {"dataset": "FL", "scale": 0.005, "seed": 3},
      "training": {"steps": 5, "lr": 0.1, "simulate": true}
    }"#;

    #[test]
    fn parses_full_program() {
        let (_builder, params) = parse_program(PROGRAM).unwrap();
        assert_eq!(params.steps, 5);
        assert!((params.lr - 0.1).abs() < 1e-6);
        assert!(params.simulate);
    }

    #[test]
    fn rejects_unknown_sampler() {
        let bad = PROGRAM.replace("NeighborSampler", "MagicSampler");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("MagicSampler"), "{err}");
    }

    #[test]
    fn rejects_graphless_program() {
        let bad = PROGRAM.replace("\"dataset\": \"FL\", \"scale\": 0.005, ", "");
        assert!(parse_program(&bad).is_err());
    }

    #[test]
    fn subgraph_sampler_variant() {
        let prog = PROGRAM.replace(
            r#"{"type": "NeighborSampler", "budgets": [5, 3], "targets": 4}"#,
            r#"{"type": "SubgraphSampler", "budget": 64, "layers": 2}"#,
        );
        let (_b, p) = parse_program(&prog).unwrap();
        assert_eq!(p.steps, 5);
    }
}
