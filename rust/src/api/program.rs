//! User-program schema: the JSON analog of the paper's Listing 1.
//!
//! A user program is a small JSON document:
//!
//! ```json
//! {
//!   "platform": "xilinx-U250",
//!   "model": {"computation": "SAGE", "hidden": [256]},
//!   "sampler": {"type": "NeighborSampler", "budgets": [10, 25], "targets": 1024},
//!   "graph": {"dataset": "FL", "scale": 0.05},
//!   "seed": 1,
//!   "training": {"steps": 100, "lr": 0.05, "eval_every": 20,
//!                "checkpoint": "run.ckpt", "checkpoint_every": 25},
//!   "serving": {"checkpoint": "run.ckpt", "workers": 4, "max_batch": 64,
//!               "cache": true}
//! }
//! ```
//!
//! [`parse_program`] turns it into a
//! [`ProgramSpec`](super::spec::ProgramSpec) — the same typed spec the
//! [`HpGnn`](super::HpGnn) builder lowers into — reporting **every**
//! problem in the document at once (see [`super::diag`]).  The spec
//! round-trips: [`ProgramSpec::to_json`](super::spec::ProgramSpec::to_json)
//! emits this exact schema, so a design's embedded program re-parses to an
//! equal spec and an emitted design doubles as a rerunnable experiment
//! file.  The `hp-gnn run` CLI subcommand executes a program end to end as
//! a [`TrainingSession`](crate::coordinator::TrainingSession) (with
//! `--resume <ckpt>` continuing from a session snapshot); `hp-gnn serve`
//! serves its `serving` section; `hp-gnn validate` prints the full
//! diagnostic list; `hp-gnn explain` prints the generated-design report.
//!
//! # Schema
//!
//! Unknown keys are rejected everywhere — a typo like `"smapler"` is a
//! diagnostic, never silently ignored (and *every* unknown key in the
//! document is reported, not just the first).
//!
//! | Section | Key | Type | Meaning |
//! |---|---|---|---|
//! | *(top level)* | `platform` | string | registered board name (`"xilinx-U250"`, `"xilinx-U280"`; case-insensitive) |
//! | | `model` | object | GNN model section |
//! | | `sampler` | object | sampling algorithm section |
//! | | `graph` | object | input graph section |
//! | | `seed` | int | training/feature-synthesis seed (≤ 2^53; default: `graph.seed`, else 1) |
//! | | `layout` | object | RMT/RRA switches (optional; default both on) |
//! | | `placement` | string | `"fpga-local"` \| `"host-streamed"` (optional; default: decided against DDR capacity) |
//! | | `training` | object | training-phase section |
//! | | `serving` | object | inference-serving section (optional) |
//! | `model` | `computation` | string | `"gcn"` \| `"sage"` (alias `"graphsage"`) \| `"gin"`, case-insensitive — exactly the names [`GnnModel::parse`](crate::sampler::values::GnnModel::parse) accepts |
//! | | `hidden` | [int] | hidden feature dims (length L-1) |
//! | `sampler` | `type` | string | `NeighborSampler` \| `SubgraphSampler` \| `LayerwiseSampler` |
//! | | `targets` | int | Neighbor/Layerwise: target vertices per batch |
//! | | `budgets` | [int] | Neighbor: per-layer fan-outs (length L) |
//! | | `budget` | int | Subgraph: vertex budget |
//! | | `layers` | int | Subgraph: model depth L |
//! | | `sizes` | [int] | Layerwise: per-layer sample sizes (length L) |
//! | `graph` | `dataset` | string | Table 4 dataset key (`FL`/`RD`/`YP`/`AP`) |
//! | | `scale` | number | dataset scale factor in (0, 1] (default 1.0) |
//! | | `edge_list` | string | path to an edge-list file (instead of `dataset`) |
//! | | `feat_dim` | int | required with `edge_list` |
//! | | `num_classes` | int | required with `edge_list` |
//! | | `path` | string | packed `HPGNNG02` out-of-core store (instead of `dataset`/`edge_list`; write one with `hp-gnn graph pack`) — the store carries its own structure, dims and version, so `scale`/`feat_dim`/`num_classes`/`seed` are rejected next to it |
//! | | `seed` | int | graph-*structure* seed (default: top-level `seed`, else 1) |
//! | `layout` | `rmt` | bool | rank-minimizing transform (default true) |
//! | | `rra` | bool | round-robin assignment (default true) |
//! | `training` | `steps` | int | total training iterations |
//! | | `lr` | number | learning rate |
//! | | `simulate` | bool | attach accelerator-simulator timing (default false) |
//! | | `eval_every` | int | evaluate every N steps; 0 disables (default 0) |
//! | | `eval_batches` | int | held-out batches per evaluation (default 2) |
//! | | `checkpoint` | string | `HPGNNS01` session-snapshot path (written every `checkpoint_every` steps and at the end) |
//! | | `checkpoint_every` | int | snapshot cadence in steps; 0 = final snapshot only (default 0) |
//! | `serving` | `checkpoint` | string | trained checkpoint to serve (`HPGNNW01` or `HPGNNS01`; `hp-gnn serve --checkpoint` overrides) |
//! | | `workers` | int | forward-executor replicas (default 2) |
//! | | `max_batch` | int | micro-batch coalescing cap; 0 = geometry capacity (default 0) |
//! | | `max_wait_us` | int | micro-batch deadline in µs (default 200) |
//! | | `queue_depth` | int | request-queue bound; admission sheds past it (default 1024) |
//! | | `cache` | bool | versioned logits cache (default false) |
//! | | `listen` | string | HTTP frontend bind address `host:port`; port 0 = ephemeral (`hp-gnn serve --listen` overrides; default: in-process only) |
//!
//! # Seed precedence
//!
//! The top-level `seed` drives training and feature synthesis; `graph.seed`
//! drives synthetic graph structure.  Each falls back to the other (so the
//! old single-`graph.seed` programs keep their exact behavior), then to 1.
//! Giving both with *different* values is a diagnostic — see
//! [`spec`](super::spec) for the rationale.

use super::spec::ProgramSpec;

/// Parse a user program into a [`ProgramSpec`], converting the full
/// diagnostic list into one `anyhow` error (each problem with its JSON
/// path).  Use [`ProgramSpec::from_json`] directly to keep the structured
/// [`Diagnostics`](super::diag::Diagnostics).
pub fn parse_program(text: &str) -> anyhow::Result<ProgramSpec> {
    Ok(ProgramSpec::from_json(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const PROGRAM: &str = r#"{
      "platform": "xilinx-U250",
      "model": {"computation": "GCN", "hidden": [8]},
      "sampler": {"type": "NeighborSampler", "budgets": [5, 3], "targets": 4},
      "graph": {"dataset": "FL", "scale": 0.005, "seed": 3},
      "training": {"steps": 5, "lr": 0.1, "simulate": true}
    }"#;

    #[test]
    fn parses_full_program() {
        let spec = parse_program(PROGRAM).unwrap();
        assert_eq!(spec.training.steps, 5);
        assert!((spec.training.lr - 0.1).abs() < 1e-6);
        assert!(spec.training.simulate);
        // Session knobs default off.
        assert_eq!(spec.training.eval_every, 0);
        assert_eq!(spec.training.eval_batches, 2);
        assert!(spec.training.checkpoint.is_none());
        assert_eq!(spec.training.checkpoint_every, 0);
        // graph.seed alone drives both seeds (back-compat).
        assert_eq!(spec.resolved_seed(), 3);
        assert_eq!(spec.structure_seed(), 3);
        // No serving section -> None.
        assert!(spec.serving.is_none());
        assert!(spec.validate().is_empty());
    }

    #[test]
    fn parses_session_training_keys() {
        let prog = PROGRAM.replace(
            r#""training": {"steps": 5, "lr": 0.1, "simulate": true}"#,
            r#""training": {"steps": 8, "lr": 0.1, "eval_every": 2, "eval_batches": 3,
                "checkpoint": "run.ckpt", "checkpoint_every": 4}"#,
        );
        let spec = parse_program(&prog).unwrap();
        assert_eq!(spec.training.eval_every, 2);
        assert_eq!(spec.training.eval_batches, 3);
        assert_eq!(
            spec.training.checkpoint.as_deref(),
            Some(std::path::Path::new("run.ckpt"))
        );
        assert_eq!(spec.training.checkpoint_every, 4);
        assert!(!spec.training.simulate);
    }

    #[test]
    fn parses_serving_and_top_level_seed() {
        let prog = PROGRAM
            .replace(
                r#""graph": {"dataset": "FL", "scale": 0.005, "seed": 3},"#,
                r#""graph": {"dataset": "FL", "scale": 0.005},
                   "seed": 3,
                   "serving": {"checkpoint": "model.bin", "workers": 4,
                               "max_batch": 64, "cache": true},"#,
            );
        let spec = parse_program(&prog).unwrap();
        assert_eq!(spec.resolved_seed(), 3);
        let s = spec.serving.as_ref().unwrap();
        assert_eq!(s.checkpoint.as_deref(), Some(std::path::Path::new("model.bin")));
        assert_eq!(s.workers, 4);
        assert_eq!(s.max_batch, 64);
        assert!(s.cache);
        // Unspecified serving knobs take their defaults.
        assert_eq!(s.max_wait_us, 200);
        assert_eq!(s.queue_depth, 1024);
        assert!(spec.validate().is_empty());
    }

    #[test]
    fn parses_serving_listen_address() {
        let prog = PROGRAM.replace(
            "\"training\":",
            r#""serving": {"listen": "127.0.0.1:8080"}, "training":"#,
        );
        let spec = parse_program(&prog).unwrap();
        assert_eq!(
            spec.serving.as_ref().unwrap().listen.as_deref(),
            Some("127.0.0.1:8080")
        );
        assert!(spec.validate().is_empty());
        // A non-host:port address is a validation diagnostic, not a crash.
        let prog = PROGRAM.replace(
            "\"training\":",
            r#""serving": {"listen": "localhost"}, "training":"#,
        );
        let spec = parse_program(&prog).unwrap();
        let d = spec.validate();
        assert!(d.iter().any(|x| x.path == "serving.listen"), "{d}");
    }

    #[test]
    fn rejects_unknown_top_level_key() {
        // The classic typo: "smapler" next to a missing "sampler".
        let bad = PROGRAM.replace("\"sampler\":", "\"smapler\":");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("smapler"), "{err}");
        // Both problems surface in the same pass.
        assert!(err.contains("sampler: missing section"), "{err}");
    }

    #[test]
    fn rejects_unknown_model_key() {
        let bad = PROGRAM.replace("\"hidden\":", "\"hiddne\":");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("hiddne") && err.contains("model"), "{err}");
    }

    #[test]
    fn rejects_unknown_sampler_key() {
        let bad = PROGRAM.replace("\"targets\": 4", "\"targets\": 4, \"budgte\": 9");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("budgte"), "{err}");
        // Keys of *other* sampler variants are also rejected per variant.
        let bad = PROGRAM.replace("\"targets\": 4", "\"targets\": 4, \"budget\": 9");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("budget") && err.contains("NeighborSampler"), "{err}");
    }

    #[test]
    fn rejects_unknown_graph_key() {
        let bad = PROGRAM.replace("\"scale\":", "\"scael\":");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("scael"), "{err}");
    }

    #[test]
    fn rejects_unknown_training_key() {
        let bad = PROGRAM.replace("\"lr\":", "\"lr ates\": 1, \"lr\":");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("lr ates"), "{err}");
    }

    #[test]
    fn rejects_unknown_serving_key() {
        let prog = PROGRAM.replace(
            "\"training\":",
            r#""serving": {"wrokers": 4}, "training":"#,
        );
        let err = parse_program(&prog).unwrap_err().to_string();
        assert!(err.contains("serving.wrokers"), "{err}");
    }

    #[test]
    fn rejects_unknown_sampler() {
        let bad = PROGRAM.replace("NeighborSampler", "MagicSampler");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("MagicSampler"), "{err}");
    }

    #[test]
    fn rejects_graphless_program() {
        let bad = PROGRAM.replace("\"dataset\": \"FL\", \"scale\": 0.005, ", "");
        // Still has "seed", so the section is present but incomplete.
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("dataset") || err.contains("edge_list"), "{err}");
    }

    #[test]
    fn reports_every_problem_in_one_error() {
        // Three independent mistakes in three different sections.
        let bad = PROGRAM
            .replace("xilinx-U250", "stratix-10")
            .replace("\"hidden\": [8]", "\"hidden\": [8, 8]")
            .replace("\"budgets\": [5, 3]", "\"budgets\": []");
        let spec = parse_program(&bad).unwrap();
        let d = spec.validate();
        let paths: Vec<&str> = d.iter().map(|x| x.path.as_str()).collect();
        assert!(paths.contains(&"platform"), "{paths:?}");
        assert!(paths.contains(&"model.hidden"), "{paths:?}");
        assert!(paths.contains(&"sampler.budgets"), "{paths:?}");
    }

    #[test]
    fn graphsage_alias_matches_schema_table() {
        // The schema table documents the aliases GnnModel::parse accepts;
        // keep them in sync.
        let prog = PROGRAM.replace("\"computation\": \"GCN\"", "\"computation\": \"graphsage\"");
        let spec = parse_program(&prog).unwrap();
        assert_eq!(
            spec.model.computation,
            crate::sampler::values::GnnModel::Sage
        );
        let prog = PROGRAM.replace("\"computation\": \"GCN\"", "\"computation\": \"GIN\"");
        assert!(parse_program(&prog).is_ok());
    }

    #[test]
    fn subgraph_sampler_variant() {
        let prog = PROGRAM.replace(
            r#"{"type": "NeighborSampler", "budgets": [5, 3], "targets": 4}"#,
            r#"{"type": "SubgraphSampler", "budget": 64, "layers": 2}"#,
        );
        let spec = parse_program(&prog).unwrap();
        assert_eq!(spec.training.steps, 5);
    }

    #[test]
    fn graph_path_mounts_a_packed_store() {
        let prog = PROGRAM.replace(
            r#""graph": {"dataset": "FL", "scale": 0.005, "seed": 3},"#,
            r#""graph": {"path": "graph.hpg"},"#,
        );
        let spec = parse_program(&prog).unwrap();
        assert!(matches!(
            spec.graph,
            super::super::spec::GraphSpec::Store { .. }
        ));
        // A store carries its own structure/dims: keys that would restate
        // them next to `path` are rejected, with the pack hint.
        let bad = PROGRAM.replace(
            r#""graph": {"dataset": "FL", "scale": 0.005, "seed": 3},"#,
            r#""graph": {"path": "graph.hpg", "scale": 0.5},"#,
        );
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("graph.scale"), "{err}");
        // Exactly one graph source: dataset + path is a diagnostic.
        let bad = PROGRAM.replace(
            r#""graph": {"dataset": "FL", "scale": 0.005, "seed": 3},"#,
            r#""graph": {"dataset": "FL", "path": "graph.hpg"},"#,
        );
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("exactly one"), "{err}");
    }

    #[test]
    fn seed_conflict_is_a_diagnostic() {
        let prog = PROGRAM.replace("\"training\":", "\"seed\": 9, \"training\":");
        let spec = parse_program(&prog).unwrap();
        let d = spec.validate();
        assert!(d.iter().any(|x| x.path == "seed"), "{d}");
        // Top-level wins for training; graph.seed keeps the structure.
        assert_eq!(spec.resolved_seed(), 9);
        assert_eq!(spec.structure_seed(), 3);
    }
}
