//! User-program parser: the JSON analog of the paper's Listing 1.
//!
//! A user program is a small JSON document:
//!
//! ```json
//! {
//!   "platform": "xilinx-U250",
//!   "model": {"computation": "SAGE", "hidden": [256]},
//!   "sampler": {"type": "NeighborSampler", "budgets": [10, 25], "targets": 1024},
//!   "graph": {"dataset": "FL", "scale": 0.05, "seed": 1},
//!   "training": {"steps": 100, "lr": 0.05, "eval_every": 20,
//!                "checkpoint": "run.ckpt", "checkpoint_every": 25}
//! }
//! ```
//!
//! `parse_program` turns it into an [`HpGnn`] builder plus training
//! parameters; the `hp-gnn run` CLI subcommand executes it end to end as a
//! [`TrainingSession`](crate::coordinator::TrainingSession) (with
//! `--resume <ckpt>` continuing from a session snapshot).
//!
//! # Schema
//!
//! Unknown keys are rejected everywhere — a typo like `"smapler"` is a
//! parse error, never silently ignored.
//!
//! | Section | Key | Type | Meaning |
//! |---|---|---|---|
//! | *(top level)* | `platform` | string | board name (`"xilinx-U250"`) |
//! | | `model` | object | GNN model section |
//! | | `sampler` | object | sampling algorithm section |
//! | | `graph` | object | input graph section |
//! | | `training` | object | training-phase section |
//! | `model` | `computation` | string | `"GCN"` \| `"SAGE"` \| `"GIN"` |
//! | | `hidden` | [int] | hidden feature dims (length L-1) |
//! | `sampler` | `type` | string | `NeighborSampler` \| `SubgraphSampler` \| `LayerwiseSampler` |
//! | | `targets` | int | Neighbor/Layerwise: target vertices per batch |
//! | | `budgets` | [int] | Neighbor: per-layer fan-outs (length L) |
//! | | `budget` | int | Subgraph: vertex budget |
//! | | `layers` | int | Subgraph: model depth L |
//! | | `sizes` | [int] | Layerwise: per-layer sample sizes (length L) |
//! | `graph` | `dataset` | string | Table 4 dataset key (`FL`/`RD`/`YP`/`AP`) |
//! | | `scale` | number | dataset scale factor (default 1.0) |
//! | | `edge_list` | string | path to an edge-list file (instead of `dataset`) |
//! | | `feat_dim` | int | required with `edge_list` |
//! | | `num_classes` | int | required with `edge_list` |
//! | | `seed` | int | graph + training seed (default 1) |
//! | `training` | `steps` | int | total training iterations |
//! | | `lr` | number | learning rate |
//! | | `simulate` | bool | attach accelerator-simulator timing (default false) |
//! | | `eval_every` | int | evaluate every N steps; 0 disables (default 0) |
//! | | `eval_batches` | int | held-out batches per evaluation (default 2) |
//! | | `checkpoint` | string | `HPGNNS01` session-snapshot path (written every `checkpoint_every` steps and at the end) |
//! | | `checkpoint_every` | int | snapshot cadence in steps; 0 = final snapshot only (default 0) |

use super::{HpGnn, SamplerSpec};
use crate::util::json::Json;

/// Training-phase parameters of a user program.
#[derive(Debug, Clone)]
pub struct TrainingParams {
    /// Total steps of the run (a resumed session trains the remainder).
    pub steps: usize,
    pub lr: f32,
    pub simulate: bool,
    /// Evaluate on held-out batches every N steps (0 = off).
    pub eval_every: usize,
    /// Batches per evaluation.
    pub eval_batches: usize,
    /// Session-snapshot path (`HPGNNS01`); `None` disables checkpointing.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Snapshot every N steps; 0 writes only the final snapshot.
    pub checkpoint_every: usize,
}

/// Reject keys outside `allowed` so typos fail loudly instead of being
/// silently ignored.
fn check_keys(section: &str, obj: &Json, allowed: &[&str]) -> anyhow::Result<()> {
    for key in obj.as_obj()?.keys() {
        anyhow::ensure!(
            allowed.contains(&key.as_str()),
            "unknown key {key:?} in {section} (allowed: {})",
            allowed.join(", ")
        );
    }
    Ok(())
}

/// Parse a user program into a ready builder + training params.
pub fn parse_program(text: &str) -> anyhow::Result<(HpGnn, TrainingParams)> {
    let doc = Json::parse(text)?;
    check_keys("the user program", &doc, &["platform", "model", "sampler", "graph", "training"])?;

    let mut builder = HpGnn::init();

    // Platform.
    match doc.get("platform")? {
        Json::Str(board) => builder = builder.platform_board(board)?,
        other => anyhow::bail!("platform must be a board name string, got {other:?}"),
    }

    // Model.
    let model = doc.get("model")?;
    check_keys("\"model\"", model, &["computation", "hidden"])?;
    builder = builder.gnn_computation(model.get("computation")?.as_str()?)?;
    builder = builder.gnn_parameters(model.get("hidden")?.usize_list()?);

    // Sampler.
    let sampler = doc.get("sampler")?;
    let spec = match sampler.get("type")?.as_str()? {
        "NeighborSampler" => {
            check_keys("\"sampler\" (NeighborSampler)", sampler, &["type", "targets", "budgets"])?;
            SamplerSpec::Neighbor {
                targets: sampler.get("targets")?.as_usize()?,
                budgets: sampler.get("budgets")?.usize_list()?,
            }
        }
        "SubgraphSampler" => {
            check_keys("\"sampler\" (SubgraphSampler)", sampler, &["type", "budget", "layers"])?;
            SamplerSpec::Subgraph {
                budget: sampler.get("budget")?.as_usize()?,
                layers: sampler.get("layers")?.as_usize()?,
            }
        }
        "LayerwiseSampler" => {
            check_keys("\"sampler\" (LayerwiseSampler)", sampler, &["type", "targets", "sizes"])?;
            SamplerSpec::Layerwise {
                targets: sampler.get("targets")?.as_usize()?,
                sizes: sampler.get("sizes")?.usize_list()?,
            }
        }
        other => anyhow::bail!(
            "unknown sampler {other:?} (NeighborSampler|SubgraphSampler|LayerwiseSampler)"
        ),
    };
    builder = builder.sampler(spec);

    // Graph.
    let graph = doc.get("graph")?;
    check_keys(
        "\"graph\"",
        graph,
        &["dataset", "scale", "edge_list", "feat_dim", "num_classes", "seed"],
    )?;
    let seed = graph.opt("seed").map(|j| j.as_usize()).transpose()?.unwrap_or(1) as u64;
    if let Some(ds) = graph.opt("dataset") {
        let scale = graph.opt("scale").map(|j| j.as_f64()).transpose()?.unwrap_or(1.0);
        builder = builder.load_dataset(ds.as_str()?, scale, seed)?;
    } else if let Some(path) = graph.opt("edge_list") {
        let mut g = crate::graph::io::load_edge_list(std::path::Path::new(path.as_str()?))?;
        g.feat_dim = graph.get("feat_dim")?.as_usize()?;
        g.num_classes = graph.get("num_classes")?.as_usize()?;
        builder = builder.load_input_graph(g);
    } else {
        anyhow::bail!("graph needs either \"dataset\" or \"edge_list\"");
    }
    builder = builder.seed(seed);

    // Training.
    let training = doc.get("training")?;
    check_keys(
        "\"training\"",
        training,
        &[
            "steps",
            "lr",
            "simulate",
            "eval_every",
            "eval_batches",
            "checkpoint",
            "checkpoint_every",
        ],
    )?;
    let opt_usize = |key: &str| -> anyhow::Result<Option<usize>> {
        Ok(training.opt(key).map(|j| j.as_usize()).transpose()?)
    };
    let params = TrainingParams {
        steps: training.get("steps")?.as_usize()?,
        lr: training.get("lr")?.as_f64()? as f32,
        simulate: training
            .opt("simulate")
            .map(|j| j.as_bool())
            .transpose()?
            .unwrap_or(false),
        eval_every: opt_usize("eval_every")?.unwrap_or(0),
        eval_batches: opt_usize("eval_batches")?.unwrap_or(2),
        checkpoint: training
            .opt("checkpoint")
            .map(|j| j.as_str())
            .transpose()?
            .map(std::path::PathBuf::from),
        checkpoint_every: opt_usize("checkpoint_every")?.unwrap_or(0),
    };

    Ok((builder, params))
}

#[cfg(test)]
mod tests {
    use super::*;

    const PROGRAM: &str = r#"{
      "platform": "xilinx-U250",
      "model": {"computation": "GCN", "hidden": [8]},
      "sampler": {"type": "NeighborSampler", "budgets": [5, 3], "targets": 4},
      "graph": {"dataset": "FL", "scale": 0.005, "seed": 3},
      "training": {"steps": 5, "lr": 0.1, "simulate": true}
    }"#;

    #[test]
    fn parses_full_program() {
        let (_builder, params) = parse_program(PROGRAM).unwrap();
        assert_eq!(params.steps, 5);
        assert!((params.lr - 0.1).abs() < 1e-6);
        assert!(params.simulate);
        // Session knobs default off.
        assert_eq!(params.eval_every, 0);
        assert_eq!(params.eval_batches, 2);
        assert!(params.checkpoint.is_none());
        assert_eq!(params.checkpoint_every, 0);
    }

    #[test]
    fn parses_session_training_keys() {
        let prog = PROGRAM.replace(
            r#""training": {"steps": 5, "lr": 0.1, "simulate": true}"#,
            r#""training": {"steps": 8, "lr": 0.1, "eval_every": 2, "eval_batches": 3,
                "checkpoint": "run.ckpt", "checkpoint_every": 4}"#,
        );
        let (_b, p) = parse_program(&prog).unwrap();
        assert_eq!(p.eval_every, 2);
        assert_eq!(p.eval_batches, 3);
        assert_eq!(p.checkpoint.as_deref(), Some(std::path::Path::new("run.ckpt")));
        assert_eq!(p.checkpoint_every, 4);
        assert!(!p.simulate);
    }

    #[test]
    fn rejects_unknown_top_level_key() {
        // The classic typo: "smapler" next to a missing "sampler".
        let bad = PROGRAM.replace("\"sampler\":", "\"smapler\":");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("smapler"), "{err}");
    }

    #[test]
    fn rejects_unknown_model_key() {
        let bad = PROGRAM.replace("\"hidden\":", "\"hiddne\":");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("hiddne") && err.contains("model"), "{err}");
    }

    #[test]
    fn rejects_unknown_sampler_key() {
        let bad = PROGRAM.replace("\"targets\": 4", "\"targets\": 4, \"budgte\": 9");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("budgte"), "{err}");
        // Keys of *other* sampler variants are also rejected per variant.
        let bad = PROGRAM.replace("\"targets\": 4", "\"targets\": 4, \"budget\": 9");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("budget") && err.contains("NeighborSampler"), "{err}");
    }

    #[test]
    fn rejects_unknown_graph_key() {
        let bad = PROGRAM.replace("\"scale\":", "\"scael\":");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("scael"), "{err}");
    }

    #[test]
    fn rejects_unknown_training_key() {
        let bad = PROGRAM.replace("\"lr\":", "\"lr ates\": 1, \"lr\":");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("lr ates"), "{err}");
    }

    #[test]
    fn rejects_unknown_sampler() {
        let bad = PROGRAM.replace("NeighborSampler", "MagicSampler");
        let err = parse_program(&bad).unwrap_err().to_string();
        assert!(err.contains("MagicSampler"), "{err}");
    }

    #[test]
    fn rejects_graphless_program() {
        let bad = PROGRAM.replace("\"dataset\": \"FL\", \"scale\": 0.005, ", "");
        assert!(parse_program(&bad).is_err());
    }

    #[test]
    fn subgraph_sampler_variant() {
        let prog = PROGRAM.replace(
            r#"{"type": "NeighborSampler", "budgets": [5, 3], "targets": 4}"#,
            r#"{"type": "SubgraphSampler", "budget": 64, "layers": 2}"#,
        );
        let (_b, p) = parse_program(&prog).unwrap();
        assert_eq!(p.steps, 5);
    }
}
