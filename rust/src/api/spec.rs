//! `ProgramSpec` — the one declarative description of an HP-GNN program.
//!
//! Every frontend converges here: the JSON user program parses into a
//! `ProgramSpec` ([`ProgramSpec::from_json`]), the [`HpGnn`](super::HpGnn)
//! builder lowers into one ([`HpGnn::spec`](super::HpGnn::spec)), and the
//! CLI subcommands drive one through an [`api::Workspace`](super::Workspace).
//! Generation, validation, serving and the DSE engine all consume the same
//! typed spec, so the frontends cannot drift.
//!
//! Two properties carry the design:
//!
//! * **Round-trip**: `from_json(to_json(spec)) == spec` for every
//!   serializable spec (asserted property-style in
//!   `rust/tests/spec_roundtrip.rs`).  An emitted design therefore doubles
//!   as a rerunnable, versionable experiment file.  The two builder-only
//!   escape hatches — an in-memory [`GraphSpec::Inline`] graph and a
//!   [`PlatformSpec::Custom`] platform — have no JSON form and make
//!   [`ProgramSpec::to_json`] return an error naming the fix.
//! * **Full-pass validation**: [`ProgramSpec::from_json`] and
//!   [`ProgramSpec::validate`] walk the *entire* document/spec and report
//!   every problem as a [`Diagnostic`](super::diag::Diagnostic) with its
//!   JSON path, instead of bailing at the first.
//!
//! The JSON schema itself is documented in [`super::program`].
//!
//! # Seeds
//!
//! Historically the seed lived only under `graph.seed`, where it silently
//! doubled as the training seed.  The spec makes the canonical location
//! explicit: the top-level `seed` drives everything — training, feature
//! synthesis, and synthetic graph structure.  `graph.seed` stays honored
//! for back-compat (old programs behave bit-identically), and giving both
//! with *different* values is a [`validate`](ProgramSpec::validate)
//! diagnostic: one program, one seed.
//!
//! Precedence, as seen by the accessors: [`ProgramSpec::resolved_seed`]
//! (training/features) prefers the top-level `seed`;
//! [`ProgramSpec::structure_seed`] (graph synthesis) prefers `graph.seed`;
//! each falls back to the other, then to `1` — so on any spec that passes
//! validation the two agree.  Seeds must fit in 53 bits (they travel
//! through JSON numbers; [`validate`](ProgramSpec::validate) enforces it).

use std::path::PathBuf;
use std::sync::Arc;

use super::diag::Diagnostics;
use super::SamplerSpec;
use crate::accel::device::FeaturePlacement;
use crate::accel::platform::{self, Platform};
use crate::graph::{datasets, Graph, GraphAccess};
use crate::layout::LayoutOptions;
use crate::sampler::values::GnnModel;
use crate::util::json::Json;

/// Target platform: a registered board name, or a custom field-by-field
/// [`Platform`] (builder-only; not serializable).
#[derive(Debug, Clone, PartialEq)]
pub enum PlatformSpec {
    /// A name in the board registry (`accel::platform::BOARDS`).
    Board(String),
    /// A custom platform built field-by-field (paper Listing 2).
    Custom(Platform),
}

impl PlatformSpec {
    /// Resolve to a concrete [`Platform`] (registry lookup for boards).
    pub fn resolve(&self) -> anyhow::Result<Platform> {
        match self {
            PlatformSpec::Board(name) => platform::by_board(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown board {name:?} (known boards: {})",
                    platform::board_names().join(", ")
                )
            }),
            PlatformSpec::Custom(p) => Ok(p.clone()),
        }
    }
}

/// GNN model section: operator + hidden dims (length L-1).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub computation: GnnModel,
    /// Hidden feature dims between the input features and the classes.
    pub hidden: Vec<usize>,
}

/// Input graph section.
#[derive(Debug, Clone)]
pub enum GraphSpec {
    /// A Table 4 dataset key instantiated at a scale factor.
    Dataset { key: String, scale: f64, seed: Option<u64> },
    /// An edge-list file plus the dims the file does not carry.
    EdgeList { path: PathBuf, feat_dim: usize, num_classes: usize, seed: Option<u64> },
    /// A packed out-of-core store (`HPGNNG02`, written by `hp-gnn graph
    /// pack`) opened via mmap — the graph never loads into RAM, and it
    /// carries its own dims, name and version.
    Store { path: PathBuf },
    /// A materialized in-memory graph (builder-only; not serializable).
    Inline(Arc<Graph>),
}

impl PartialEq for GraphSpec {
    fn eq(&self, other: &GraphSpec) -> bool {
        match (self, other) {
            (
                GraphSpec::Dataset { key: a, scale: b, seed: c },
                GraphSpec::Dataset { key: x, scale: y, seed: z },
            ) => a == x && b == y && c == z,
            (
                GraphSpec::EdgeList { path: a, feat_dim: b, num_classes: c, seed: d },
                GraphSpec::EdgeList { path: w, feat_dim: x, num_classes: y, seed: z },
            ) => a == w && b == x && c == y && d == z,
            (GraphSpec::Store { path: a }, GraphSpec::Store { path: b }) => a == b,
            // Inline graphs are equal only when they are the same graph.
            (GraphSpec::Inline(a), GraphSpec::Inline(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

impl GraphSpec {
    /// The graph-section seed, when one was given.
    pub fn seed(&self) -> Option<u64> {
        match self {
            GraphSpec::Dataset { seed, .. } | GraphSpec::EdgeList { seed, .. } => *seed,
            GraphSpec::Store { .. } | GraphSpec::Inline(_) => None,
        }
    }

    /// Materialize the graph, returning it plus the *full-scale* feature
    /// row count (`DistributeData()` decides placement against the real
    /// matrix, not a scaled instance).  Store graphs come back as an
    /// mmap-backed [`GraphStore`](crate::graph::store::GraphStore) behind
    /// the same access trait — the caller cannot tell (and must not care)
    /// whether neighbors resolve from RAM or disk.
    pub fn materialize(
        &self,
        structure_seed: u64,
    ) -> anyhow::Result<(Arc<dyn GraphAccess>, usize)> {
        match self {
            GraphSpec::Dataset { key, scale, .. } => {
                let spec = datasets::by_key(key)
                    .ok_or_else(|| anyhow::anyhow!("unknown dataset {key:?}"))?;
                Ok((Arc::new(spec.scale(*scale).instantiate(structure_seed)), spec.nodes))
            }
            GraphSpec::EdgeList { path, feat_dim, num_classes, .. } => {
                let mut g = crate::graph::io::load_edge_list(path)?;
                g.feat_dim = *feat_dim;
                g.num_classes = *num_classes;
                let rows = g.num_vertices();
                Ok((Arc::new(g), rows))
            }
            GraphSpec::Store { path } => {
                let store = crate::graph::store::GraphStore::open(path)?;
                let rows = store.num_vertices();
                Ok((Arc::new(store), rows))
            }
            GraphSpec::Inline(g) => {
                let rows = g.num_vertices();
                Ok((Arc::clone(g) as Arc<dyn GraphAccess>, rows))
            }
        }
    }
}

/// Training-phase section (the old `TrainingParams`, now part of the spec).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingSpec {
    /// Total steps of the run (a resumed session trains the remainder).
    pub steps: usize,
    pub lr: f32,
    /// Attach accelerator-simulator timing to every batch.
    pub simulate: bool,
    /// Evaluate on held-out batches every N steps (0 = off).
    pub eval_every: usize,
    /// Batches per evaluation.
    pub eval_batches: usize,
    /// Session-snapshot path (`HPGNNS01`); `None` disables checkpointing.
    pub checkpoint: Option<PathBuf>,
    /// Snapshot every N steps; 0 writes only the final snapshot.
    pub checkpoint_every: usize,
}

impl Default for TrainingSpec {
    fn default() -> TrainingSpec {
        TrainingSpec {
            steps: 0,
            lr: 0.05,
            simulate: false,
            eval_every: 0,
            eval_batches: 2,
            checkpoint: None,
            checkpoint_every: 0,
        }
    }
}

/// Serving section — the knobs `hp-gnn serve` and
/// [`ServeConfig`](crate::serve::ServeConfig) share, expressible in the
/// user program so a deployment is part of the same versionable artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingSpec {
    /// Trained checkpoint to serve (`HPGNNW01` weights or an `HPGNNS01`
    /// session snapshot).  `None` means the caller must supply one
    /// (e.g. `hp-gnn serve --checkpoint`).
    pub checkpoint: Option<PathBuf>,
    /// Forward-executor replicas in the worker pool.
    pub workers: usize,
    /// Micro-batch coalescing cap; 0 = the geometry's target capacity.
    pub max_batch: usize,
    /// Micro-batch deadline in microseconds.
    pub max_wait_us: u64,
    /// Bound of the request queue (enqueue blocks when full).
    pub queue_depth: usize,
    /// Enable the versioned logits cache for repeat vertices.
    pub cache: bool,
    /// HTTP listen address (`host:port`; port 0 = ephemeral) for the
    /// network frontend.  `None` serves in-process only; the
    /// `hp-gnn serve --listen` flag overrides whatever is here.
    pub listen: Option<String>,
}

impl Default for ServingSpec {
    /// Mirrors [`ServeConfig`](crate::serve::ServeConfig)'s defaults.
    fn default() -> ServingSpec {
        ServingSpec {
            checkpoint: None,
            workers: 2,
            max_batch: 0,
            max_wait_us: 200,
            queue_depth: 1024,
            cache: false,
            listen: None,
        }
    }
}

/// A complete, typed HP-GNN program: platform, model, sampler, graph,
/// seeds, layout switches, training phase and (optionally) serving.
///
/// See the [module docs](self) for the round-trip and full-pass-validation
/// contracts, and [`super::program`] for the JSON schema.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramSpec {
    pub platform: PlatformSpec,
    pub model: ModelSpec,
    pub sampler: SamplerSpec,
    pub graph: GraphSpec,
    /// Top-level training/feature seed; see the module docs for precedence.
    pub seed: Option<u64>,
    /// RMT/RRA layout switches (Table 6 ablation; default: all on).
    pub layout: LayoutOptions,
    /// Explicit feature placement (`DistributeData()`); `None` decides
    /// automatically against the board's DDR capacity.
    pub placement: Option<FeaturePlacement>,
    pub training: TrainingSpec,
    pub serving: Option<ServingSpec>,
}

impl ProgramSpec {
    /// The training/feature-synthesis seed: top-level `seed`, else
    /// `graph.seed`, else 1.
    pub fn resolved_seed(&self) -> u64 {
        self.seed.or(self.graph.seed()).unwrap_or(1)
    }

    /// The synthetic graph-structure seed: `graph.seed`, else the
    /// top-level `seed`, else 1.
    pub fn structure_seed(&self) -> u64 {
        self.graph.seed().or(self.seed).unwrap_or(1)
    }

    // ---- validation ------------------------------------------------------

    /// Walk the whole spec and report **every** problem (empty = clean).
    /// Cheap and pure: no graph materialization, no artifact registry.
    pub fn validate(&self) -> Diagnostics {
        let mut d = Diagnostics::new();

        if let PlatformSpec::Board(name) = &self.platform {
            if platform::by_board(name).is_none() {
                d.push_hint(
                    "platform",
                    format!("unknown board {name:?}"),
                    format!("known boards: {}", platform::board_names().join(", ")),
                );
            }
        }

        let layers = self.sampler.layers();
        if self.model.hidden.len() + 1 != layers {
            d.push_hint(
                "model.hidden",
                format!("{} hidden dims for {} sampler layers", self.model.hidden.len(), layers),
                "GNN_Parameters lists the L-1 dims between the input features and the classes",
            );
        }
        if self.model.hidden.contains(&0) {
            d.push("model.hidden", "hidden dims must be at least 1");
        }

        match &self.sampler {
            SamplerSpec::Neighbor { targets, budgets } => {
                if *targets == 0 {
                    d.push("sampler.targets", "must be at least 1");
                }
                if budgets.is_empty() {
                    d.push("sampler.budgets", "must list at least one per-layer fan-out");
                } else if budgets.contains(&0) {
                    d.push("sampler.budgets", "per-layer fan-outs must be at least 1");
                }
            }
            SamplerSpec::Subgraph { budget, layers } => {
                if *budget == 0 {
                    d.push("sampler.budget", "must be at least 1");
                }
                if *layers == 0 {
                    d.push("sampler.layers", "must be at least 1");
                }
            }
            SamplerSpec::Layerwise { targets, sizes } => {
                if *targets == 0 {
                    d.push("sampler.targets", "must be at least 1");
                }
                if sizes.is_empty() {
                    d.push("sampler.sizes", "must list at least one per-layer sample size");
                } else if sizes.contains(&0) {
                    d.push("sampler.sizes", "per-layer sample sizes must be at least 1");
                }
            }
        }

        match &self.graph {
            GraphSpec::Dataset { key, scale, .. } => {
                if datasets::by_key(key).is_none() {
                    let known: Vec<&str> = datasets::ALL.iter().map(|ds| ds.key).collect();
                    d.push_hint(
                        "graph.dataset",
                        format!("unknown dataset {key:?}"),
                        format!("known datasets: {}", known.join(", ")),
                    );
                }
                if !(*scale > 0.0 && *scale <= 1.0) {
                    d.push("graph.scale", format!("{scale} is outside (0, 1]"));
                }
            }
            GraphSpec::EdgeList { feat_dim, num_classes, .. } => {
                if *feat_dim == 0 {
                    d.push("graph.feat_dim", "must be at least 1");
                }
                if *num_classes == 0 {
                    d.push("graph.num_classes", "must be at least 1");
                }
            }
            GraphSpec::Store { path } => {
                // A store program names an on-disk artifact; `hp-gnn
                // validate` is the preflight that catches a missing or
                // malformed file before a long run starts, so probe the
                // header here (cheap: 80 bytes + the file length).
                match crate::graph::store::probe(path) {
                    Ok(_) => {}
                    Err(e) => d.push_hint(
                        "graph.path",
                        format!("{}: {e:#}", path.display()),
                        "pack one with: hp-gnn graph pack --dataset <key> --out <path>",
                    ),
                }
            }
            GraphSpec::Inline(g) => {
                if g.feat_dim == 0 {
                    d.push("graph", "inline graph has no feature dimension");
                }
                if g.num_classes == 0 {
                    d.push("graph", "inline graph has no class count");
                }
            }
        }

        if let (Some(top), Some(gs)) = (self.seed, self.graph.seed()) {
            if top != gs {
                d.push_hint(
                    "seed",
                    format!("top-level seed {top} conflicts with graph.seed {gs}"),
                    "one seed drives graph synthesis, feature synthesis and training — \
                     drop graph.seed (the top-level seed is the canonical one)",
                );
            }
        }
        // Seeds travel through JSON numbers: 53 bits is the lossless bound.
        const MAX_JSON_INT: u64 = 1 << 53;
        if self.seed.is_some_and(|s| s > MAX_JSON_INT) {
            d.push("seed", "must fit in 53 bits (seeds travel through JSON numbers)");
        }
        if self.graph.seed().is_some_and(|s| s > MAX_JSON_INT) {
            d.push("graph.seed", "must fit in 53 bits (seeds travel through JSON numbers)");
        }

        let t = &self.training;
        if !t.lr.is_finite() || t.lr < 0.0 {
            d.push("training.lr", format!("{} is not a usable learning rate", t.lr));
        }
        if t.checkpoint_every > 0 && t.checkpoint.is_none() {
            d.push_hint(
                "training.checkpoint_every",
                "set without training.checkpoint",
                "name a snapshot path, or drop the cadence",
            );
        }
        if t.eval_every > 0 && t.eval_batches == 0 {
            d.push("training.eval_batches", "eval_every is set but eval_batches is 0");
        }

        if let Some(s) = &self.serving {
            if s.workers == 0 {
                d.push("serving.workers", "must be at least 1");
            }
            if s.queue_depth == 0 {
                d.push("serving.queue_depth", "must be at least 1");
            }
            if s.max_wait_us > MAX_JSON_INT {
                d.push("serving.max_wait_us", "must fit in 53 bits (travels through JSON)");
            }
            if let Some(listen) = &s.listen {
                let port_ok = listen
                    .rsplit_once(':')
                    .map(|(host, port)| !host.is_empty() && port.parse::<u16>().is_ok())
                    .unwrap_or(false);
                if !port_ok {
                    d.push_hint(
                        "serving.listen",
                        format!("{listen:?} is not a host:port address"),
                        "use e.g. \"127.0.0.1:8080\" (port 0 picks an ephemeral port)",
                    );
                }
            }
        }

        d
    }

    // ---- JSON ------------------------------------------------------------

    /// Parse a JSON user program, collecting **every** problem — unknown
    /// keys, wrong types, missing sections — before failing.
    pub fn from_json(text: &str) -> Result<ProgramSpec, Diagnostics> {
        let doc = match Json::parse(text) {
            Ok(doc) => doc,
            Err(e) => return Err(Diagnostics::one("$", e.to_string())),
        };
        if doc.as_obj().is_err() {
            return Err(Diagnostics::one("$", "user program must be a JSON object"));
        }
        let mut d = Diagnostics::new();
        check_keys(
            &doc,
            "",
            &[
                "platform", "model", "sampler", "graph", "training", "serving", "seed",
                "layout", "placement",
            ],
            &mut d,
        );

        let platform = parse_platform(&doc, &mut d);
        let model = parse_model(&doc, &mut d);
        let sampler = parse_sampler(&doc, &mut d);
        let graph = parse_graph(&doc, &mut d);
        let seed = opt_seed(&doc, "", "seed", &mut d);
        let layout = parse_layout(&doc, &mut d);
        let placement = parse_placement(&doc, &mut d);
        let training = parse_training(&doc, &mut d);
        let serving = parse_serving(&doc, &mut d);

        match (platform, model, sampler, graph, training) {
            (Some(platform), Some(model), Some(sampler), Some(graph), Some(training))
                if d.is_empty() =>
            {
                Ok(ProgramSpec {
                    platform,
                    model,
                    sampler,
                    graph,
                    seed,
                    layout,
                    placement,
                    training,
                    serving,
                })
            }
            _ => Err(d),
        }
    }

    /// Serialize to the same JSON schema [`from_json`](Self::from_json)
    /// parses, such that `from_json(to_json(spec).pretty()) == spec`.
    ///
    /// Errors only on the two builder escape hatches with no JSON form:
    /// an [`GraphSpec::Inline`] graph or a [`PlatformSpec::Custom`]
    /// platform.
    pub fn to_json(&self) -> anyhow::Result<Json> {
        // JSON numbers are f64: refuse u64 values that would round —
        // emitting a lossy seed would silently break the round-trip
        // contract (validate() diagnoses the same bound).
        const MAX_JSON_INT: u64 = 1 << 53;
        for (field, value) in [
            ("seed", self.seed),
            ("graph.seed", self.graph.seed()),
            ("serving.max_wait_us", self.serving.as_ref().map(|s| s.max_wait_us)),
        ] {
            if value.is_some_and(|v| v > MAX_JSON_INT) {
                anyhow::bail!("{field} does not fit in a JSON number (53-bit limit)");
            }
        }
        let board = match &self.platform {
            PlatformSpec::Board(name) => name.clone(),
            PlatformSpec::Custom(p) => anyhow::bail!(
                "custom platform {:?} has no JSON form — register it as a named board \
                 (accel::platform::BOARDS) to serialize this program",
                p.name
            ),
        };
        let graph = match &self.graph {
            GraphSpec::Dataset { key, scale, seed } => {
                let mut pairs = vec![
                    ("dataset", Json::str(key.clone())),
                    ("scale", Json::num(*scale)),
                ];
                if let Some(seed) = seed {
                    pairs.push(("seed", Json::num(*seed as f64)));
                }
                Json::obj(pairs)
            }
            GraphSpec::EdgeList { path, feat_dim, num_classes, seed } => {
                let path = path.to_str().ok_or_else(|| {
                    anyhow::anyhow!("edge-list path {path:?} is not valid UTF-8")
                })?;
                let mut pairs = vec![
                    ("edge_list", Json::str(path)),
                    ("feat_dim", Json::num(*feat_dim as f64)),
                    ("num_classes", Json::num(*num_classes as f64)),
                ];
                if let Some(seed) = seed {
                    pairs.push(("seed", Json::num(*seed as f64)));
                }
                Json::obj(pairs)
            }
            GraphSpec::Store { path } => {
                let path = path.to_str().ok_or_else(|| {
                    anyhow::anyhow!("store path {path:?} is not valid UTF-8")
                })?;
                Json::obj(vec![("path", Json::str(path))])
            }
            GraphSpec::Inline(g) => anyhow::bail!(
                "inline graph {:?} has no JSON form — load it from a dataset key or an \
                 edge_list file to serialize this program",
                g.name
            ),
        };
        let sampler = match &self.sampler {
            SamplerSpec::Neighbor { targets, budgets } => Json::obj(vec![
                ("type", Json::str("NeighborSampler")),
                ("targets", Json::num(*targets as f64)),
                ("budgets", usize_arr(budgets)),
            ]),
            SamplerSpec::Subgraph { budget, layers } => Json::obj(vec![
                ("type", Json::str("SubgraphSampler")),
                ("budget", Json::num(*budget as f64)),
                ("layers", Json::num(*layers as f64)),
            ]),
            SamplerSpec::Layerwise { targets, sizes } => Json::obj(vec![
                ("type", Json::str("LayerwiseSampler")),
                ("targets", Json::num(*targets as f64)),
                ("sizes", usize_arr(sizes)),
            ]),
        };
        let t = &self.training;
        let mut training = vec![
            ("steps", Json::num(t.steps as f64)),
            ("lr", Json::num(t.lr as f64)),
            ("simulate", Json::Bool(t.simulate)),
            ("eval_every", Json::num(t.eval_every as f64)),
            ("eval_batches", Json::num(t.eval_batches as f64)),
            ("checkpoint_every", Json::num(t.checkpoint_every as f64)),
        ];
        if let Some(ckpt) = &t.checkpoint {
            training.push(("checkpoint", path_json(ckpt)?));
        }

        let mut pairs = vec![
            ("platform", Json::str(board)),
            (
                "model",
                Json::obj(vec![
                    ("computation", Json::str(self.model.computation.as_str())),
                    ("hidden", usize_arr(&self.model.hidden)),
                ]),
            ),
            ("sampler", sampler),
            ("graph", graph),
            ("training", Json::obj(training)),
        ];
        if let Some(seed) = self.seed {
            pairs.push(("seed", Json::num(seed as f64)));
        }
        if self.layout != LayoutOptions::all() {
            pairs.push((
                "layout",
                Json::obj(vec![
                    ("rmt", Json::Bool(self.layout.rmt)),
                    ("rra", Json::Bool(self.layout.rra)),
                ]),
            ));
        }
        if let Some(p) = self.placement {
            pairs.push((
                "placement",
                Json::str(match p {
                    FeaturePlacement::FpgaLocal => "fpga-local",
                    FeaturePlacement::HostStreamed => "host-streamed",
                }),
            ));
        }
        if let Some(s) = &self.serving {
            let mut serving = vec![
                ("workers", Json::num(s.workers as f64)),
                ("max_batch", Json::num(s.max_batch as f64)),
                ("max_wait_us", Json::num(s.max_wait_us as f64)),
                ("queue_depth", Json::num(s.queue_depth as f64)),
                ("cache", Json::Bool(s.cache)),
            ];
            if let Some(ckpt) = &s.checkpoint {
                serving.push(("checkpoint", path_json(ckpt)?));
            }
            if let Some(listen) = &s.listen {
                serving.push(("listen", Json::str(listen.clone())));
            }
            pairs.push(("serving", Json::obj(serving)));
        }
        Ok(Json::obj(pairs))
    }
}

fn usize_arr(values: &[usize]) -> Json {
    Json::arr(values.iter().map(|&v| Json::num(v as f64)).collect())
}

fn path_json(path: &std::path::Path) -> anyhow::Result<Json> {
    Ok(Json::str(path.to_str().ok_or_else(|| {
        anyhow::anyhow!("path {path:?} is not valid UTF-8")
    })?))
}

// ---- parsing helpers (each pushes diagnostics instead of bailing) --------

fn at(section: &str, key: &str) -> String {
    if section.is_empty() {
        key.to_string()
    } else {
        format!("{section}.{key}")
    }
}

/// Reject keys outside `allowed` so typos fail loudly — one diagnostic per
/// unknown key, never just the first.
fn check_keys(obj: &Json, section: &str, allowed: &[&str], d: &mut Diagnostics) {
    let Ok(map) = obj.as_obj() else { return };
    for key in map.keys() {
        if !allowed.contains(&key.as_str()) {
            d.push_hint(
                at(section, key),
                if section.is_empty() {
                    "unknown key".to_string()
                } else {
                    format!("unknown key in \"{section}\"")
                },
                format!("allowed: {}", allowed.join(", ")),
            );
        }
    }
}

/// A required section: present and an object, else a diagnostic.
fn req_section<'j>(doc: &'j Json, name: &str, d: &mut Diagnostics) -> Option<&'j Json> {
    match doc.opt(name) {
        None => {
            d.push(name, "missing section");
            None
        }
        Some(section) => {
            if section.as_obj().is_err() {
                d.push(name, "must be a JSON object");
                return None;
            }
            Some(section)
        }
    }
}

fn req_usize(obj: &Json, section: &str, key: &str, d: &mut Diagnostics) -> Option<usize> {
    match obj.opt(key) {
        None => {
            d.push(at(section, key), "missing");
            None
        }
        Some(j) => match j.as_usize() {
            Ok(v) => Some(v),
            Err(e) => {
                d.push(at(section, key), e.to_string());
                None
            }
        },
    }
}

fn req_str<'j>(obj: &'j Json, section: &str, key: &str, d: &mut Diagnostics) -> Option<&'j str> {
    match obj.opt(key) {
        None => {
            d.push(at(section, key), "missing");
            None
        }
        Some(j) => match j.as_str() {
            Ok(v) => Some(v),
            Err(e) => {
                d.push(at(section, key), e.to_string());
                None
            }
        },
    }
}

fn req_usize_list(obj: &Json, section: &str, key: &str, d: &mut Diagnostics) -> Option<Vec<usize>> {
    match obj.opt(key) {
        None => {
            d.push(at(section, key), "missing");
            None
        }
        Some(j) => match j.usize_list() {
            Ok(v) => Some(v),
            Err(e) => {
                d.push(at(section, key), e.to_string());
                None
            }
        },
    }
}

fn opt_usize(obj: &Json, section: &str, key: &str, default: usize, d: &mut Diagnostics) -> usize {
    match obj.opt(key) {
        None => default,
        Some(j) => match j.as_usize() {
            Ok(v) => v,
            Err(e) => {
                d.push(at(section, key), e.to_string());
                default
            }
        },
    }
}

fn opt_bool(obj: &Json, section: &str, key: &str, default: bool, d: &mut Diagnostics) -> bool {
    match obj.opt(key) {
        None => default,
        Some(j) => match j.as_bool() {
            Ok(v) => v,
            Err(e) => {
                d.push(at(section, key), e.to_string());
                default
            }
        },
    }
}

fn opt_f64(obj: &Json, section: &str, key: &str, default: f64, d: &mut Diagnostics) -> f64 {
    match obj.opt(key) {
        None => default,
        Some(j) => match j.as_f64() {
            Ok(v) => v,
            Err(e) => {
                d.push(at(section, key), e.to_string());
                default
            }
        },
    }
}

fn opt_seed(obj: &Json, section: &str, key: &str, d: &mut Diagnostics) -> Option<u64> {
    match obj.opt(key) {
        None => None,
        Some(j) => match j.as_usize() {
            Ok(v) => Some(v as u64),
            Err(e) => {
                d.push(at(section, key), e.to_string());
                None
            }
        },
    }
}

fn opt_string(obj: &Json, section: &str, key: &str, d: &mut Diagnostics) -> Option<String> {
    match obj.opt(key) {
        None => None,
        Some(j) => match j.as_str() {
            Ok(v) => Some(v.to_string()),
            Err(e) => {
                d.push(at(section, key), e.to_string());
                None
            }
        },
    }
}

fn opt_path(obj: &Json, section: &str, key: &str, d: &mut Diagnostics) -> Option<PathBuf> {
    match obj.opt(key) {
        None => None,
        Some(j) => match j.as_str() {
            Ok(v) => Some(PathBuf::from(v)),
            Err(e) => {
                d.push(at(section, key), e.to_string());
                None
            }
        },
    }
}

fn parse_platform(doc: &Json, d: &mut Diagnostics) -> Option<PlatformSpec> {
    match doc.opt("platform") {
        None => {
            d.push_hint(
                "platform",
                "missing section",
                format!("a board name string; known boards: {}", platform::board_names().join(", ")),
            );
            None
        }
        Some(j) => match j.as_str() {
            Ok(board) => Some(PlatformSpec::Board(board.to_string())),
            Err(_) => {
                d.push("platform", "must be a board name string");
                None
            }
        },
    }
}

fn parse_model(doc: &Json, d: &mut Diagnostics) -> Option<ModelSpec> {
    let model = req_section(doc, "model", d)?;
    check_keys(model, "model", &["computation", "hidden"], d);
    let computation = match req_str(model, "model", "computation", d) {
        None => None,
        Some(s) => match GnnModel::parse(s) {
            Ok(m) => Some(m),
            Err(e) => {
                d.push_hint(
                    "model.computation",
                    e.to_string(),
                    "gcn | sage (alias: graphsage) | gin, case-insensitive",
                );
                None
            }
        },
    };
    let hidden = req_usize_list(model, "model", "hidden", d);
    Some(ModelSpec { computation: computation?, hidden: hidden? })
}

fn parse_sampler(doc: &Json, d: &mut Diagnostics) -> Option<SamplerSpec> {
    let sampler = req_section(doc, "sampler", d)?;
    let kind = req_str(sampler, "sampler", "type", d)?.to_string();
    match kind.as_str() {
        "NeighborSampler" => {
            check_keys_variant(sampler, "NeighborSampler", &["type", "targets", "budgets"], d);
            let targets = req_usize(sampler, "sampler", "targets", d);
            let budgets = req_usize_list(sampler, "sampler", "budgets", d);
            Some(SamplerSpec::Neighbor { targets: targets?, budgets: budgets? })
        }
        "SubgraphSampler" => {
            check_keys_variant(sampler, "SubgraphSampler", &["type", "budget", "layers"], d);
            let budget = req_usize(sampler, "sampler", "budget", d);
            let layers = req_usize(sampler, "sampler", "layers", d);
            Some(SamplerSpec::Subgraph { budget: budget?, layers: layers? })
        }
        "LayerwiseSampler" => {
            check_keys_variant(sampler, "LayerwiseSampler", &["type", "targets", "sizes"], d);
            let targets = req_usize(sampler, "sampler", "targets", d);
            let sizes = req_usize_list(sampler, "sampler", "sizes", d);
            Some(SamplerSpec::Layerwise { targets: targets?, sizes: sizes? })
        }
        other => {
            d.push_hint(
                "sampler.type",
                format!("unknown sampler {other:?}"),
                "NeighborSampler | SubgraphSampler | LayerwiseSampler",
            );
            None
        }
    }
}

/// Per-variant key check: an unknown key's diagnostic names the variant
/// (a `budget` under `NeighborSampler` is almost certainly a mix-up with
/// `SubgraphSampler`).
fn check_keys_variant(obj: &Json, variant: &str, allowed: &[&str], d: &mut Diagnostics) {
    let Ok(map) = obj.as_obj() else { return };
    for key in map.keys() {
        if !allowed.contains(&key.as_str()) {
            d.push_hint(
                at("sampler", key),
                format!("unknown key for {variant}"),
                format!("allowed: {}", allowed.join(", ")),
            );
        }
    }
}

fn parse_graph(doc: &Json, d: &mut Diagnostics) -> Option<GraphSpec> {
    let graph = req_section(doc, "graph", d)?;
    check_keys(
        graph,
        "graph",
        &["dataset", "scale", "edge_list", "feat_dim", "num_classes", "seed", "path"],
        d,
    );
    let seed = opt_seed(graph, "graph", "seed", d);
    let has_dataset = graph.opt("dataset").is_some();
    let has_edge_list = graph.opt("edge_list").is_some();
    let has_store = graph.opt("path").is_some();
    if usize::from(has_dataset) + usize::from(has_edge_list) + usize::from(has_store) > 1 {
        d.push("graph", "give exactly one of \"dataset\", \"edge_list\" or \"path\"");
        return None;
    }
    if has_store {
        for key in ["scale", "feat_dim", "num_classes", "seed"] {
            if graph.opt(key).is_some() {
                d.push_hint(
                    at("graph", key),
                    "not meaningful with \"path\"",
                    "a packed store carries its own structure, dims and version",
                );
            }
        }
        let path = req_str(graph, "graph", "path", d).map(PathBuf::from)?;
        return Some(GraphSpec::Store { path });
    }
    if has_dataset {
        for key in ["feat_dim", "num_classes"] {
            if graph.opt(key).is_some() {
                d.push_hint(
                    at("graph", key),
                    "only meaningful with \"edge_list\"",
                    "dataset graphs carry their own dims",
                );
            }
        }
        let key = req_str(graph, "graph", "dataset", d)?.to_string();
        let scale = opt_f64(graph, "graph", "scale", 1.0, d);
        Some(GraphSpec::Dataset { key, scale, seed })
    } else if has_edge_list {
        if graph.opt("scale").is_some() {
            d.push_hint(
                "graph.scale",
                "only meaningful with \"dataset\"",
                "edge-list graphs load at their file's size",
            );
        }
        let path = req_str(graph, "graph", "edge_list", d).map(PathBuf::from);
        let feat_dim = req_usize(graph, "graph", "feat_dim", d);
        let num_classes = req_usize(graph, "graph", "num_classes", d);
        Some(GraphSpec::EdgeList {
            path: path?,
            feat_dim: feat_dim?,
            num_classes: num_classes?,
            seed,
        })
    } else {
        d.push("graph", "needs one of \"dataset\", \"edge_list\" or \"path\"");
        None
    }
}

fn parse_layout(doc: &Json, d: &mut Diagnostics) -> LayoutOptions {
    match doc.opt("layout") {
        None => LayoutOptions::all(),
        Some(layout) => {
            if layout.as_obj().is_err() {
                d.push("layout", "must be a JSON object");
                return LayoutOptions::all();
            }
            check_keys(layout, "layout", &["rmt", "rra"], d);
            LayoutOptions {
                rmt: opt_bool(layout, "layout", "rmt", true, d),
                rra: opt_bool(layout, "layout", "rra", true, d),
            }
        }
    }
}

fn parse_placement(doc: &Json, d: &mut Diagnostics) -> Option<FeaturePlacement> {
    let j = doc.opt("placement")?;
    match j.as_str() {
        Ok("fpga-local") => Some(FeaturePlacement::FpgaLocal),
        Ok("host-streamed") => Some(FeaturePlacement::HostStreamed),
        Ok(other) => {
            d.push_hint(
                "placement",
                format!("unknown placement {other:?}"),
                "fpga-local | host-streamed (omit to decide automatically)",
            );
            None
        }
        Err(e) => {
            d.push("placement", e.to_string());
            None
        }
    }
}

fn parse_training(doc: &Json, d: &mut Diagnostics) -> Option<TrainingSpec> {
    let training = req_section(doc, "training", d)?;
    check_keys(
        training,
        "training",
        &[
            "steps",
            "lr",
            "simulate",
            "eval_every",
            "eval_batches",
            "checkpoint",
            "checkpoint_every",
        ],
        d,
    );
    let steps = req_usize(training, "training", "steps", d);
    let lr = match training.opt("lr") {
        None => {
            d.push("training.lr", "missing");
            None
        }
        Some(j) => match j.as_f64() {
            Ok(v) => Some(v as f32),
            Err(e) => {
                d.push("training.lr", e.to_string());
                None
            }
        },
    };
    let defaults = TrainingSpec::default();
    let spec = TrainingSpec {
        steps: steps?,
        lr: lr?,
        simulate: opt_bool(training, "training", "simulate", defaults.simulate, d),
        eval_every: opt_usize(training, "training", "eval_every", defaults.eval_every, d),
        eval_batches: opt_usize(training, "training", "eval_batches", defaults.eval_batches, d),
        checkpoint: opt_path(training, "training", "checkpoint", d),
        checkpoint_every: opt_usize(
            training,
            "training",
            "checkpoint_every",
            defaults.checkpoint_every,
            d,
        ),
    };
    Some(spec)
}

fn parse_serving(doc: &Json, d: &mut Diagnostics) -> Option<ServingSpec> {
    let serving = doc.opt("serving")?;
    if serving.as_obj().is_err() {
        d.push("serving", "must be a JSON object");
        return None;
    }
    check_keys(
        serving,
        "serving",
        &["checkpoint", "workers", "max_batch", "max_wait_us", "queue_depth", "cache", "listen"],
        d,
    );
    let defaults = ServingSpec::default();
    Some(ServingSpec {
        checkpoint: opt_path(serving, "serving", "checkpoint", d),
        listen: opt_string(serving, "serving", "listen", d),
        workers: opt_usize(serving, "serving", "workers", defaults.workers, d),
        max_batch: opt_usize(serving, "serving", "max_batch", defaults.max_batch, d),
        max_wait_us: opt_usize(serving, "serving", "max_wait_us", defaults.max_wait_us as usize, d)
            as u64,
        queue_depth: opt_usize(serving, "serving", "queue_depth", defaults.queue_depth, d),
        cache: opt_bool(serving, "serving", "cache", defaults.cache, d),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal() -> ProgramSpec {
        ProgramSpec {
            platform: PlatformSpec::Board("xilinx-U250".to_string()),
            model: ModelSpec { computation: GnnModel::Gcn, hidden: vec![8] },
            sampler: SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] },
            graph: GraphSpec::Dataset { key: "FL".to_string(), scale: 0.005, seed: Some(3) },
            seed: None,
            layout: LayoutOptions::all(),
            placement: None,
            training: TrainingSpec { steps: 5, lr: 0.1, ..Default::default() },
            serving: None,
        }
    }

    #[test]
    fn minimal_spec_is_clean_and_round_trips() {
        let spec = minimal();
        assert!(spec.validate().is_empty());
        let text = spec.to_json().unwrap().pretty();
        let again = ProgramSpec::from_json(&text).unwrap();
        assert_eq!(again, spec);
    }

    #[test]
    fn full_spec_round_trips() {
        let mut spec = minimal();
        spec.seed = Some(3);
        spec.layout = LayoutOptions { rmt: false, rra: true };
        spec.placement = Some(FeaturePlacement::HostStreamed);
        spec.training = TrainingSpec {
            steps: 12,
            lr: 0.05,
            simulate: true,
            eval_every: 4,
            eval_batches: 3,
            checkpoint: Some(PathBuf::from("run.ckpt")),
            checkpoint_every: 6,
        };
        spec.serving = Some(ServingSpec {
            checkpoint: Some(PathBuf::from("model.bin")),
            workers: 4,
            max_batch: 64,
            max_wait_us: 150,
            queue_depth: 256,
            cache: true,
            listen: Some("127.0.0.1:8080".to_string()),
        });
        assert!(spec.validate().is_empty());
        let text = spec.to_json().unwrap().pretty();
        let again = ProgramSpec::from_json(&text).unwrap();
        assert_eq!(again, spec);
    }

    #[test]
    fn seed_precedence_and_conflict() {
        let mut spec = minimal();
        // graph.seed alone drives both (back-compat).
        assert_eq!(spec.resolved_seed(), 3);
        assert_eq!(spec.structure_seed(), 3);
        // A top-level seed takes over training; graph.seed keeps structure.
        spec.seed = Some(9);
        assert_eq!(spec.resolved_seed(), 9);
        assert_eq!(spec.structure_seed(), 3);
        // ...but differing values is flagged.
        let d = spec.validate();
        assert_eq!(d.len(), 1, "{d}");
        assert!(d.iter().any(|x| x.path == "seed"), "{d}");
        // Equal values are fine.
        spec.seed = Some(3);
        assert!(spec.validate().is_empty());
        // Neither given: default 1.
        spec.seed = None;
        spec.graph = GraphSpec::Dataset { key: "FL".into(), scale: 0.005, seed: None };
        assert_eq!(spec.resolved_seed(), 1);
        assert_eq!(spec.structure_seed(), 1);
    }

    #[test]
    fn validate_reports_every_problem_in_one_pass() {
        let mut spec = minimal();
        spec.platform = PlatformSpec::Board("stratix-10".to_string());
        spec.model.hidden = vec![8, 8]; // 2 hidden dims for a 2-layer sampler
        spec.sampler = SamplerSpec::Neighbor { targets: 4, budgets: vec![] };
        let d = spec.validate();
        let paths: Vec<&str> = d.iter().map(|x| x.path.as_str()).collect();
        assert!(paths.contains(&"platform"), "{paths:?}");
        assert!(paths.contains(&"model.hidden"), "{paths:?}");
        assert!(paths.contains(&"sampler.budgets"), "{paths:?}");
        assert!(d.len() >= 3, "{d}");
    }

    #[test]
    fn from_json_collects_problems_across_sections() {
        // Three independent parse-stage mistakes: a typo'd top-level key
        // (which also leaves "sampler" missing) and a bad training type.
        let text = r#"{
          "platform": "xilinx-U250",
          "model": {"computation": "GCN", "hidden": [8]},
          "smapler": {"type": "NeighborSampler", "budgets": [5, 3], "targets": 4},
          "graph": {"dataset": "FL", "scale": 0.005},
          "training": {"steps": "five", "lr": 0.1}
        }"#;
        let d = ProgramSpec::from_json(text).unwrap_err();
        let paths: Vec<&str> = d.iter().map(|x| x.path.as_str()).collect();
        assert!(paths.contains(&"smapler"), "{paths:?}");
        assert!(paths.contains(&"sampler"), "{paths:?}");
        assert!(paths.contains(&"training.steps"), "{paths:?}");
    }

    #[test]
    fn inline_and_custom_have_no_json_form() {
        let mut spec = minimal();
        spec.graph = GraphSpec::Inline(Arc::new(crate::graph::generator::uniform(
            50, 200, true, 1,
        )));
        let err = spec.to_json().unwrap_err().to_string();
        assert!(err.contains("no JSON form"), "{err}");
        let mut spec = minimal();
        spec.platform = PlatformSpec::Custom(Platform::alveo_u250());
        let err = spec.to_json().unwrap_err().to_string();
        assert!(err.contains("no JSON form"), "{err}");
    }

    #[test]
    fn oversized_seed_is_diagnosed_not_silently_rounded() {
        // A >53-bit seed cannot survive a JSON number; the write side must
        // refuse it instead of letting to_json emit a rounded value that
        // re-parses to a different (or no) seed.
        let mut spec = minimal();
        spec.seed = Some(1u64 << 60);
        spec.graph = GraphSpec::Dataset { key: "FL".into(), scale: 0.005, seed: None };
        let d = spec.validate();
        assert!(d.iter().any(|x| x.path == "seed" && x.reason.contains("53")), "{d}");
        // ...and to_json refuses even on an unvalidated spec.
        let err = spec.to_json().unwrap_err().to_string();
        assert!(err.contains("53-bit"), "{err}");
    }

    #[test]
    fn serving_defaults_mirror_serve_config() {
        // An empty `"serving": {}` section and *no* serving section must
        // configure the server identically: ServingSpec::default has to
        // track ServeConfig::default field for field.
        let spec = ServingSpec::default();
        let cfg = crate::serve::ServeConfig::default();
        assert_eq!(spec.workers, cfg.workers);
        assert_eq!(spec.max_batch, cfg.max_batch);
        assert_eq!(spec.max_wait_us, cfg.max_wait.as_micros() as u64);
        assert_eq!(spec.queue_depth, cfg.queue_depth);
        assert_eq!(spec.cache, cfg.cache);
    }

    #[test]
    fn bad_listen_addresses_are_diagnosed() {
        let mut spec = minimal();
        for bad in ["8080", "localhost", ":8080", "127.0.0.1:", "127.0.0.1:notaport"] {
            spec.serving =
                Some(ServingSpec { listen: Some(bad.to_string()), ..Default::default() });
            let d = spec.validate();
            assert!(
                d.iter().any(|x| x.path == "serving.listen"),
                "{bad:?} passed validation: {d}"
            );
        }
        for good in ["127.0.0.1:0", "0.0.0.0:8080", "[::1]:443", "gnn.internal:9090"] {
            spec.serving =
                Some(ServingSpec { listen: Some(good.to_string()), ..Default::default() });
            let d = spec.validate();
            assert!(d.is_empty(), "{good:?} rejected: {d}");
        }
    }

    #[test]
    fn non_default_scale_checks() {
        let mut spec = minimal();
        spec.graph = GraphSpec::Dataset { key: "FL".into(), scale: 0.0, seed: None };
        let d = spec.validate();
        assert!(d.iter().any(|x| x.path == "graph.scale"), "{d}");
    }
}
