//! High-level programming interface — the paper's Table 1 API.
//!
//! The paper's user program (Listing 1) is a dozen lines: specify
//! platform, GNN parameters, computation, sampler, input graph; call
//! `GenerateDesign()`; call `Start_training()`.  [`HpGnn`] is that flow as
//! a rust builder; [`program`] parses the same thing from a JSON "user
//! program" file.
//!
//! `GenerateDesign()` here performs what the paper's software + hardware
//! generators do: runs the DSE engine to pick the accelerator
//! configuration, selects the AOT artifact geometry (the "bitstream"), and
//! sizes the sampler thread pool — returning a [`GeneratedDesign`] that
//! can start training immediately.

pub mod program;

use std::path::Path;
use std::sync::Arc;

use crate::accel::device::FeaturePlacement;
use crate::accel::platform::Platform;
use crate::coordinator::{TrainConfig, TrainReport, TrainingSession};
use crate::dse::{explore, DseProblem, DseResult};
use crate::graph::{datasets, Graph};
use crate::layout::pad::EdgeOverflow;
use crate::layout::LayoutOptions;
use crate::perf::{BatchGeometry, KappaEstimator, ModelShape, ResourceCoefficients};
use crate::runtime::{Kind, Runtime};
use crate::sampler::{
    layerwise::LayerwiseSampler, neighbor::NeighborSampler, subgraph::SubgraphSampler, Sampler,
};
use crate::sampler::values::GnnModel;
use crate::serve::{ServeConfig, Server};
use crate::util::json::Json;

/// Sampling algorithm + parameters (`Sampler('NeighborSampler', L=2,
/// budgets=[10, 25])` in Listing 2).
#[derive(Debug, Clone, PartialEq)]
pub enum SamplerSpec {
    Neighbor { targets: usize, budgets: Vec<usize> },
    Subgraph { budget: usize, layers: usize },
    Layerwise { targets: usize, sizes: Vec<usize> },
}

impl SamplerSpec {
    pub fn layers(&self) -> usize {
        match self {
            SamplerSpec::Neighbor { budgets, .. } => budgets.len(),
            SamplerSpec::Subgraph { layers, .. } => *layers,
            SamplerSpec::Layerwise { sizes, .. } => sizes.len(),
        }
    }

    pub fn build(&self) -> Box<dyn Sampler> {
        match self {
            SamplerSpec::Neighbor { targets, budgets } => {
                Box::new(NeighborSampler::new(*targets, budgets.clone()))
            }
            SamplerSpec::Subgraph { budget, layers } => {
                Box::new(SubgraphSampler::new(*budget, *layers))
            }
            SamplerSpec::Layerwise { targets, sizes } => {
                Box::new(LayerwiseSampler::new(*targets, sizes.clone()))
            }
        }
    }

    /// Table 2 batch shape for the DSE engine.
    pub fn batch_geometry(&self, g: &Graph) -> BatchGeometry {
        match self {
            SamplerSpec::Neighbor { targets, budgets } => {
                BatchGeometry::neighbor_capped(*targets, budgets, g.num_vertices())
            }
            SamplerSpec::Subgraph { budget, layers } => {
                let kappa = KappaEstimator::from_stats(g.num_vertices(), g.num_edges());
                BatchGeometry::subgraph(*budget, *layers, &kappa)
            }
            SamplerSpec::Layerwise { targets, sizes } => {
                let kappa = KappaEstimator::from_stats(g.num_vertices(), g.num_edges());
                let mut s = sizes.clone();
                s.push(*targets);
                BatchGeometry::layerwise(&s, &kappa)
            }
        }
    }
}

/// The GNN abstraction the program parser extracts (paper Fig. 2): model
/// configuration + mini-batch configuration.
#[derive(Debug, Clone)]
pub struct GnnAbstraction {
    pub model: GnnModel,
    pub feat: Vec<usize>,
    pub sampler: SamplerSpec,
    pub batch: BatchGeometry,
}

/// Builder implementing the Table 1 call sequence.
#[derive(Default, Debug)]
pub struct HpGnn {
    platform: Option<Platform>,
    model: Option<GnnModel>,
    hidden: Vec<usize>,
    sampler: Option<SamplerSpec>,
    graph: Option<Graph>,
    layout: LayoutOptions,
    seed: u64,
    placement_override: Option<FeaturePlacement>,
    /// Full-dataset statistics behind a scaled instance, if known
    /// (placement must be decided against the *real* feature matrix).
    full_nodes: Option<usize>,
}

impl HpGnn {
    /// `Init()` — start a program.
    pub fn init() -> HpGnn {
        HpGnn { layout: LayoutOptions::all(), seed: 7, ..Default::default() }
    }

    /// `PlatformParameters(board='xilinx-U250')` or a custom board.
    pub fn platform_board(mut self, board: &str) -> anyhow::Result<HpGnn> {
        anyhow::ensure!(
            board.eq_ignore_ascii_case("xilinx-u250"),
            "unknown board {board:?} (known: xilinx-U250; use .platform() for custom)"
        );
        self.platform = Some(Platform::alveo_u250());
        Ok(self)
    }

    pub fn platform(mut self, p: Platform) -> HpGnn {
        self.platform = Some(p);
        self
    }

    /// `GNN_Computation('SAGE' | 'GCN')`.
    pub fn gnn_computation(mut self, model: &str) -> anyhow::Result<HpGnn> {
        self.model = Some(GnnModel::parse(model)?);
        Ok(self)
    }

    /// `GNN_Parameters(L, hidden)` — hidden dims between f0 and classes.
    pub fn gnn_parameters(mut self, hidden: Vec<usize>) -> HpGnn {
        self.hidden = hidden;
        self
    }

    /// `Sampler(...)`.
    pub fn sampler(mut self, spec: SamplerSpec) -> HpGnn {
        self.sampler = Some(spec);
        self
    }

    /// `LoadInputGraph()` — a materialized graph (use
    /// `datasets::DatasetSpec::scale(..).instantiate(..)` or graph::io).
    pub fn load_input_graph(mut self, g: Graph) -> HpGnn {
        self.graph = Some(g);
        self
    }

    /// Convenience: a Table 4 dataset at a scale factor.
    pub fn load_dataset(mut self, key: &str, scale: f64, seed: u64) -> anyhow::Result<HpGnn> {
        let spec = datasets::by_key(key)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {key:?}"))?;
        self.full_nodes = Some(spec.nodes);
        Ok(self.load_input_graph(spec.scale(scale).instantiate(seed)))
    }

    /// `DistributeData()` — explicitly place the feature matrix (default:
    /// decided automatically against the board's DDR capacity).
    pub fn distribute_data(mut self, placement: FeaturePlacement) -> HpGnn {
        self.placement_override = Some(placement);
        self
    }

    /// Layout optimization switches (Table 6 ablation; default: all on).
    pub fn layout(mut self, layout: LayoutOptions) -> HpGnn {
        self.layout = layout;
        self
    }

    pub fn seed(mut self, seed: u64) -> HpGnn {
        self.seed = seed;
        self
    }

    /// `GenerateDesign()` — DSE + artifact-geometry selection + sampler
    /// thread sizing.  `runtime` provides the artifact registry (the
    /// "bitstream library").
    pub fn generate_design(self, runtime: &Runtime) -> anyhow::Result<GeneratedDesign> {
        let platform = self.platform.ok_or_else(|| anyhow::anyhow!("PlatformParameters() missing"))?;
        let model = self.model.ok_or_else(|| anyhow::anyhow!("GNN_Computation() missing"))?;
        let sampler = self.sampler.ok_or_else(|| anyhow::anyhow!("Sampler() missing"))?;
        let graph = self.graph.ok_or_else(|| anyhow::anyhow!("LoadInputGraph() missing"))?;
        anyhow::ensure!(graph.feat_dim > 0, "graph has no feature dimension");
        anyhow::ensure!(graph.num_classes > 0, "graph has no class count");
        anyhow::ensure!(
            self.hidden.len() + 1 == sampler.layers(),
            "GNN_Parameters: {} hidden dims for {} layers (need L-1)",
            self.hidden.len(),
            sampler.layers()
        );

        let mut feat = vec![graph.feat_dim];
        feat.extend(&self.hidden);
        feat.push(graph.num_classes);

        let batch = sampler.batch_geometry(&graph);
        let abstraction = GnnAbstraction { model, feat: feat.clone(), sampler, batch };

        // Hardware generator: Algorithm 4 on the target platform.
        let dse = explore(
            &platform,
            &DseProblem {
                geom: abstraction.batch.clone(),
                model: ModelShape {
                    feat: feat.clone(),
                    sage_concat: model == GnnModel::Sage,
                },
                layout: self.layout,
                coeff: ResourceCoefficients::default(),
                t_sampling_single: None,
            },
        );

        // Software generator: pick the smallest artifact geometry whose
        // bounds cover the sampler's worst case.
        let geometry = select_geometry(runtime, model, &abstraction)?;

        // DistributeData(): features go to FPGA DDR when the *full-scale*
        // matrix fits (paper §3.1), else stay in host memory and stream.
        let feature_rows = self.full_nodes.unwrap_or(graph.num_vertices());
        let feature_bytes = feature_rows * graph.feat_dim * 4;
        let placement = self.placement_override.unwrap_or(if feature_bytes <= platform.ddr_bytes {
            FeaturePlacement::FpgaLocal
        } else {
            FeaturePlacement::HostStreamed
        });

        Ok(GeneratedDesign {
            platform,
            accel: dse,
            geometry,
            layout: self.layout,
            placement,
            graph: Arc::new(graph),
            abstraction,
            seed: self.seed,
        })
    }
}

/// Pick an artifact geometry for the abstraction (smallest that fits).
fn select_geometry(
    runtime: &Runtime,
    model: GnnModel,
    abs: &GnnAbstraction,
) -> anyhow::Result<String> {
    let sampler = abs.sampler.build();
    let mut candidates: Vec<&crate::runtime::ArtifactSpec> = Vec::new();
    for name in runtime.manifest.names() {
        let spec = runtime.manifest.get(name)?;
        if spec.model.as_str() != model.artifact_key() || spec.kind != Kind::TrainStep {
            continue;
        }
        let geom = &spec.geometry;
        if geom.layers() != sampler.num_layers() || geom.f != abs.feat {
            continue;
        }
        // Vertex bounds must hold; edge overflow is tolerable only for
        // subgraph batches (truncation policy).
        let fits_b = abs.batch.b.iter().zip(&geom.b).all(|(need, have)| need <= have);
        let fits_e = match abs.sampler {
            SamplerSpec::Neighbor { .. } => {
                abs.batch.e.iter().zip(&geom.e).all(|(need, have)| need <= have)
            }
            _ => true,
        };
        if fits_b && fits_e {
            candidates.push(spec);
        }
    }
    // Prefer geometries whose shape class matches the sampler (NS batches
    // shrink per layer; SS batches keep b constant), then the smallest.
    let want_equal = !matches!(abs.sampler, SamplerSpec::Neighbor { .. });
    candidates.sort_by_key(|s| {
        let b = &s.geometry.b;
        let is_equal = b.windows(2).all(|w| w[0] == w[1]);
        (usize::from(is_equal != want_equal), s.geometry.total_vertices())
    });
    candidates
        .first()
        .map(|s| s.geometry.name.clone())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no artifact geometry fits model={} layers={} feat={:?} batch b={:?} — \
                 add a geometry to python/compile/geometry.py and `make artifacts`",
                model.as_str(),
                sampler.num_layers(),
                abs.feat,
                abs.batch.b,
            )
        })
}

/// Output of `GenerateDesign()`: everything needed to run training.
///
/// The graph is held in an `Arc` so each [`session`](Self::session) shares
/// it with the producer threads instead of deep-copying it (the feature
/// matrix alone can be hundreds of MB at full dataset scale).
#[derive(Debug)]
pub struct GeneratedDesign {
    pub platform: Platform,
    pub accel: DseResult,
    pub geometry: String,
    pub layout: LayoutOptions,
    pub placement: FeaturePlacement,
    pub graph: Arc<Graph>,
    pub abstraction: GnnAbstraction,
    pub seed: u64,
}

impl GeneratedDesign {
    /// The [`TrainConfig`] this design trains with (the generated host
    /// program's knobs): artifact geometry, DSE-sized sampler thread pool,
    /// overflow policy matched to the sampler class.
    pub fn train_config(&self, steps: usize, lr: f32, simulate: bool) -> TrainConfig {
        TrainConfig {
            model: self.abstraction.model,
            optimizer: Default::default(),
            geometry: self.geometry.clone(),
            steps,
            lr,
            seed: self.seed,
            layout: self.layout,
            sampler_threads: self.accel.sampler_threads.unwrap_or(2),
            compute_threads: crate::util::threadpool::default_threads(),
            overflow: match self.abstraction.sampler {
                SamplerSpec::Neighbor { .. } => EdgeOverflow::Error,
                _ => EdgeOverflow::TruncateKeepSelf,
            },
            simulate: simulate.then(|| (self.platform.clone(), self.accel.config)),
            log_every: 0,
            value_fn: None,
        }
    }

    /// Open a [`TrainingSession`] on this design: compiles the artifact,
    /// spawns the producer pipeline, and hands back pull-based control
    /// (`step`/`run_for`/`evaluate`/`save`/`finish` plus the
    /// `on_step`/`on_eval` hooks).
    pub fn session<'rt>(
        &self,
        runtime: &'rt Runtime,
        lr: f32,
        simulate: bool,
    ) -> anyhow::Result<TrainingSession<'rt>> {
        TrainingSession::new(
            runtime,
            Arc::clone(&self.graph),
            Arc::from(self.abstraction.sampler.build()),
            self.train_config(0, lr, simulate),
        )
    }

    /// [`session`](Self::session) restored from an `HPGNNS01` snapshot:
    /// weights, optimizer state and the RNG cursor come from `checkpoint`,
    /// and training continues bit-exactly where the snapshotted run left
    /// off (reference backend).
    pub fn resume_session<'rt>(
        &self,
        runtime: &'rt Runtime,
        lr: f32,
        simulate: bool,
        checkpoint: &Path,
    ) -> anyhow::Result<TrainingSession<'rt>> {
        TrainingSession::resume(
            runtime,
            Arc::clone(&self.graph),
            Arc::from(self.abstraction.sampler.build()),
            self.train_config(0, lr, simulate),
            checkpoint,
        )
    }

    /// Serving configuration for this design: the training-time model,
    /// artifact geometry, layout, overflow policy and seed, with the
    /// serving knobs (workers, micro-batching, cache) at their defaults —
    /// override fields before handing it to [`server`](Self::server).
    pub fn serve_config(&self) -> ServeConfig {
        ServeConfig::from_train(&self.train_config(0, 0.0, false))
    }

    /// Open an inference [`Server`] on this design from a trained
    /// checkpoint (either `HPGNNW01` weights or an `HPGNNS01` session
    /// snapshot): compiles one forward executor replica per worker,
    /// spawns the micro-batcher + worker pool, and answers
    /// [`classify`](Server::classify) requests until shutdown.
    pub fn server(
        &self,
        runtime: &Runtime,
        cfg: ServeConfig,
        checkpoint: &Path,
    ) -> anyhow::Result<Server> {
        Server::from_checkpoint(
            runtime,
            Arc::clone(&self.graph),
            Arc::from(self.abstraction.sampler.build()),
            cfg,
            checkpoint,
        )
    }

    /// `Start_training()` — run Algorithm 2 for `steps` iterations (the
    /// paper's fire-and-forget host program: a session driven start to
    /// finish in one call).
    pub fn start_training(
        &self,
        runtime: &Runtime,
        steps: usize,
        lr: f32,
        simulate: bool,
    ) -> anyhow::Result<TrainReport> {
        let mut session = self.session(runtime, lr, simulate)?;
        session.run_for(steps)?;
        Ok(session.finish())
    }

    /// The generated-design summary (the analog of Listing 3's generated
    /// host program + accelerator configuration).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("board", Json::str(self.platform.name.clone())),
            ("model", Json::str(self.abstraction.model.as_str())),
            (
                "feat_dims",
                Json::arr(self.abstraction.feat.iter().map(|&f| Json::num(f as f64)).collect()),
            ),
            ("artifact_geometry", Json::str(self.geometry.clone())),
            (
                "feature_placement",
                Json::str(match self.placement {
                    FeaturePlacement::FpgaLocal => "fpga-local",
                    FeaturePlacement::HostStreamed => "host-streamed",
                }),
            ),
            ("accel_n_scatter_pes", Json::num(self.accel.config.n as f64)),
            ("accel_m_macs", Json::num(self.accel.config.m as f64)),
            ("predicted_nvtps", Json::num(self.accel.nvtps)),
            ("dsp_utilization", Json::num(self.accel.utilization.dsp)),
            ("lut_utilization", Json::num(self.accel.utilization.lut)),
            ("uram_utilization", Json::num(self.accel.utilization.uram)),
            ("bram_utilization", Json::num(self.accel.utilization.bram)),
            (
                "batch_b",
                Json::arr(self.abstraction.batch.b.iter().map(|&b| Json::num(b as f64)).collect()),
            ),
            (
                "batch_e",
                Json::arr(self.abstraction.batch.e.iter().map(|&e| Json::num(e as f64)).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_spec_builds_and_sizes() {
        let g = crate::graph::generator::uniform(1000, 8000, true, 1);
        let ns = SamplerSpec::Neighbor { targets: 16, budgets: vec![5, 3] };
        assert_eq!(ns.layers(), 2);
        let geom = ns.batch_geometry(&g);
        assert_eq!(geom.b[2], 16);
        assert!(geom.b[0] > geom.b[1]);
        let ss = SamplerSpec::Subgraph { budget: 100, layers: 2 };
        let geom = ss.batch_geometry(&g);
        assert_eq!(geom.b, vec![100, 100, 100]);
        let s = ns.build();
        assert_eq!(s.num_layers(), 2);
    }

    /// An artifact-less runtime on the always-available reference backend
    /// (these tests only exercise builder validation).
    fn empty_runtime() -> Runtime {
        Runtime::with_backend(
            crate::runtime::Manifest::from_specs(Vec::new()).unwrap(),
            Box::new(crate::runtime::ReferenceBackend::default()),
        )
    }

    #[test]
    fn builder_validates_missing_pieces() {
        let rt = empty_runtime();
        let err = HpGnn::init().generate_design(&rt).unwrap_err().to_string();
        assert!(err.contains("PlatformParameters"), "{err}");
        let err = HpGnn::init()
            .platform(Platform::alveo_u250())
            .generate_design(&rt)
            .unwrap_err()
            .to_string();
        assert!(err.contains("GNN_Computation"), "{err}");
    }

    #[test]
    fn unknown_board_rejected() {
        assert!(HpGnn::init().platform_board("stratix-10").is_err());
        assert!(HpGnn::init().platform_board("Xilinx-U250").is_ok());
    }

    #[test]
    fn hidden_dims_must_match_depth() {
        let rt = empty_runtime();
        let mut g = crate::graph::generator::uniform(100, 500, true, 2);
        g.feat_dim = 16;
        g.num_classes = 4;
        let err = HpGnn::init()
            .platform(Platform::alveo_u250())
            .gnn_computation("gcn")
            .unwrap()
            .gnn_parameters(vec![8, 8]) // 2 hidden for 2 layers: wrong
            .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![3, 3] })
            .load_input_graph(g)
            .generate_design(&rt)
            .unwrap_err()
            .to_string();
        assert!(err.contains("GNN_Parameters"), "{err}");
    }
}
