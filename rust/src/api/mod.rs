//! High-level programming interface — the paper's Table 1 API behind one
//! declarative spec.
//!
//! The paper's user program (Listing 1) is a dozen lines: specify
//! platform, GNN parameters, computation, sampler, input graph; call
//! `GenerateDesign()`; call `Start_training()`.  Three frontends express
//! that program here, and all of them converge on the same typed
//! [`ProgramSpec`](spec::ProgramSpec):
//!
//! * the [`HpGnn`] builder (the Table 1 call sequence as rust) lowers into
//!   a spec via [`HpGnn::spec`];
//! * the JSON user program parses into one via
//!   [`ProgramSpec::from_json`](spec::ProgramSpec::from_json) (schema in
//!   [`program`]);
//! * the `hp-gnn` CLI subcommands construct one from flags.
//!
//! [`ProgramSpec::build`] then performs what the paper's software +
//! hardware generators do: runs the DSE engine to pick the accelerator
//! configuration, selects the AOT artifact geometry (the "bitstream"), and
//! sizes the sampler thread pool — returning a [`GeneratedDesign`] that
//! can start training immediately.  Validation is full-pass: every problem
//! in a spec is reported at once as [`diag::Diagnostic`]s, not just the
//! first.
//!
//! [`Workspace`] is the runtime-owning facade: open it once over an
//! artifact directory and design/train/serve without threading `&Runtime`
//! through every call:
//!
//! ```no_run
//! # use hp_gnn::api::{ProgramSpec, Workspace};
//! # fn demo(spec: &ProgramSpec) -> anyhow::Result<()> {
//! let ws = Workspace::open(std::path::Path::new("artifacts"))?;
//! let design = ws.design(spec)?;
//! println!("{}", design.explain());
//! let _session = design.session()?;
//! # Ok(()) }
//! ```

pub mod diag;
pub mod program;
pub mod spec;

use std::path::Path;
use std::sync::Arc;

use crate::accel::device::FeaturePlacement;
use crate::accel::platform::Platform;
use crate::coordinator::{TrainConfig, TrainReport, TrainingSession};
use crate::dse::{explore, DseProblem, DseResult};
use crate::graph::store::DynamicGraph;
use crate::graph::{datasets, Graph, GraphAccess};
use crate::layout::pad::EdgeOverflow;
use crate::layout::LayoutOptions;
use crate::perf::{BatchGeometry, KappaEstimator, ModelShape, ResourceCoefficients};
use crate::runtime::{Kind, Runtime};
use crate::sampler::values::GnnModel;
use crate::sampler::{
    layerwise::LayerwiseSampler, neighbor::NeighborSampler, subgraph::SubgraphSampler, Sampler,
};
use crate::serve::{ServeConfig, Server};
use crate::util::json::Json;

pub use diag::{Diagnostic, Diagnostics};
pub use spec::{GraphSpec, ModelSpec, PlatformSpec, ProgramSpec, ServingSpec, TrainingSpec};

/// Sampling algorithm + parameters (`Sampler('NeighborSampler', L=2,
/// budgets=[10, 25])` in Listing 2).
#[derive(Debug, Clone, PartialEq)]
pub enum SamplerSpec {
    Neighbor { targets: usize, budgets: Vec<usize> },
    Subgraph { budget: usize, layers: usize },
    Layerwise { targets: usize, sizes: Vec<usize> },
}

impl SamplerSpec {
    pub fn layers(&self) -> usize {
        match self {
            SamplerSpec::Neighbor { budgets, .. } => budgets.len(),
            SamplerSpec::Subgraph { layers, .. } => *layers,
            SamplerSpec::Layerwise { sizes, .. } => sizes.len(),
        }
    }

    pub fn build(&self) -> Box<dyn Sampler> {
        match self {
            SamplerSpec::Neighbor { targets, budgets } => {
                Box::new(NeighborSampler::new(*targets, budgets.clone()))
            }
            SamplerSpec::Subgraph { budget, layers } => {
                Box::new(SubgraphSampler::new(*budget, *layers))
            }
            SamplerSpec::Layerwise { targets, sizes } => {
                Box::new(LayerwiseSampler::new(*targets, sizes.clone()))
            }
        }
    }

    /// Table 2 batch shape for the DSE engine.
    pub fn batch_geometry(&self, g: &dyn GraphAccess) -> BatchGeometry {
        self.batch_geometry_stats(g.num_vertices(), g.num_edges())
    }

    /// [`batch_geometry`](Self::batch_geometry) from graph *statistics*
    /// alone — what `hp-gnn dse` uses to size against a full published
    /// dataset without materializing it.
    pub fn batch_geometry_stats(&self, nodes: usize, edges: usize) -> BatchGeometry {
        match self {
            SamplerSpec::Neighbor { targets, budgets } => {
                BatchGeometry::neighbor_capped(*targets, budgets, nodes)
            }
            SamplerSpec::Subgraph { budget, layers } => {
                let kappa = KappaEstimator::from_stats(nodes, edges);
                BatchGeometry::subgraph(*budget, *layers, &kappa)
            }
            SamplerSpec::Layerwise { targets, sizes } => {
                let kappa = KappaEstimator::from_stats(nodes, edges);
                let mut s = sizes.clone();
                s.push(*targets);
                BatchGeometry::layerwise(&s, &kappa)
            }
        }
    }

    fn describe(&self) -> String {
        match self {
            SamplerSpec::Neighbor { targets, budgets } => {
                format!("NeighborSampler targets={targets} budgets={budgets:?}")
            }
            SamplerSpec::Subgraph { budget, layers } => {
                format!("SubgraphSampler budget={budget} layers={layers}")
            }
            SamplerSpec::Layerwise { targets, sizes } => {
                format!("LayerwiseSampler targets={targets} sizes={sizes:?}")
            }
        }
    }
}

/// The GNN abstraction the program lowering extracts (paper Fig. 2): model
/// configuration + mini-batch configuration.
#[derive(Debug, Clone)]
pub struct GnnAbstraction {
    pub model: GnnModel,
    pub feat: Vec<usize>,
    pub sampler: SamplerSpec,
    pub batch: BatchGeometry,
}

/// Builder implementing the Table 1 call sequence.  It accumulates a
/// [`ProgramSpec`] piece by piece — [`spec`](Self::spec) hands the spec
/// out, [`generate_design`](Self::generate_design) builds it directly.
///
/// Two escape hatches go beyond what the JSON frontend can express: an
/// in-memory graph ([`load_input_graph`](Self::load_input_graph)) and a
/// field-by-field custom [`platform`](Self::platform).  Specs using them
/// work everywhere except [`ProgramSpec::to_json`].
#[derive(Default, Debug)]
pub struct HpGnn {
    platform: Option<PlatformSpec>,
    model: Option<GnnModel>,
    hidden: Vec<usize>,
    sampler: Option<SamplerSpec>,
    graph: Option<GraphSpec>,
    layout: LayoutOptions,
    seed: Option<u64>,
    placement: Option<FeaturePlacement>,
    training: TrainingSpec,
    serving: Option<ServingSpec>,
}

impl HpGnn {
    /// `Init()` — start a program.
    pub fn init() -> HpGnn {
        HpGnn { layout: LayoutOptions::all(), ..Default::default() }
    }

    /// `PlatformParameters(board='xilinx-U250')` — any name in the board
    /// registry ([`crate::accel::platform::BOARDS`]); unknown boards error
    /// with the full registry listing.
    pub fn platform_board(mut self, board: &str) -> anyhow::Result<HpGnn> {
        anyhow::ensure!(
            crate::accel::platform::by_board(board).is_some(),
            "unknown board {board:?} (known boards: {}; use .platform() for custom)",
            crate::accel::platform::board_names().join(", ")
        );
        self.platform = Some(PlatformSpec::Board(board.to_string()));
        Ok(self)
    }

    /// A custom board built field-by-field (paper Listing 2).
    pub fn platform(mut self, p: Platform) -> HpGnn {
        self.platform = Some(PlatformSpec::Custom(p));
        self
    }

    /// `GNN_Computation('SAGE' | 'GCN' | 'GIN')`.
    pub fn gnn_computation(mut self, model: &str) -> anyhow::Result<HpGnn> {
        self.model = Some(GnnModel::parse(model)?);
        Ok(self)
    }

    /// `GNN_Parameters(L, hidden)` — hidden dims between f0 and classes.
    pub fn gnn_parameters(mut self, hidden: Vec<usize>) -> HpGnn {
        self.hidden = hidden;
        self
    }

    /// `Sampler(...)`.
    pub fn sampler(mut self, spec: SamplerSpec) -> HpGnn {
        self.sampler = Some(spec);
        self
    }

    /// `LoadInputGraph()` — a materialized in-memory graph (use
    /// `datasets::DatasetSpec::scale(..).instantiate(..)` or graph::io).
    /// Builder-only: such a spec has no JSON form.
    pub fn load_input_graph(mut self, g: Graph) -> HpGnn {
        self.graph = Some(GraphSpec::Inline(Arc::new(g)));
        self
    }

    /// Convenience: a Table 4 dataset at a scale factor.  `seed` is the
    /// graph-*structure* seed (`graph.seed` in the spec).
    pub fn load_dataset(mut self, key: &str, scale: f64, seed: u64) -> anyhow::Result<HpGnn> {
        anyhow::ensure!(datasets::by_key(key).is_some(), "unknown dataset {key:?}");
        self.graph = Some(GraphSpec::Dataset { key: key.to_string(), scale, seed: Some(seed) });
        Ok(self)
    }

    /// An edge-list file plus the dims the file does not carry.
    pub fn load_edge_list(mut self, path: &Path, feat_dim: usize, num_classes: usize) -> HpGnn {
        self.graph = Some(GraphSpec::EdgeList {
            path: path.to_path_buf(),
            feat_dim,
            num_classes,
            seed: None,
        });
        self
    }

    /// `DistributeData()` — explicitly place the feature matrix (default:
    /// decided automatically against the board's DDR capacity).
    pub fn distribute_data(mut self, placement: FeaturePlacement) -> HpGnn {
        self.placement = Some(placement);
        self
    }

    /// Layout optimization switches (Table 6 ablation; default: all on).
    pub fn layout(mut self, layout: LayoutOptions) -> HpGnn {
        self.layout = layout;
        self
    }

    /// The training/feature seed (the spec's top-level `seed`).
    ///
    /// When never called, the seed resolves like a JSON program's:
    /// `graph.seed` (e.g. the `load_dataset` seed argument), else 1.
    /// Note this changed with the spec unification — the builder
    /// previously defaulted to a training seed of 7 independent of the
    /// graph seed, so builder programs that relied on the implicit 7
    /// (and any `HPGNNS01` snapshots they wrote) must now say `.seed(7)`.
    pub fn seed(mut self, seed: u64) -> HpGnn {
        self.seed = Some(seed);
        self
    }

    /// Training-phase parameters (steps, lr, eval/checkpoint cadences).
    pub fn training(mut self, training: TrainingSpec) -> HpGnn {
        self.training = training;
        self
    }

    /// Serving section (worker pool, micro-batching, cache, checkpoint).
    pub fn serving(mut self, serving: ServingSpec) -> HpGnn {
        self.serving = Some(serving);
        self
    }

    /// Lower the builder into a [`ProgramSpec`].  Missing required pieces
    /// are reported together as [`Diagnostics`] (named after the paper's
    /// API calls).
    pub fn spec(self) -> Result<ProgramSpec, Diagnostics> {
        let mut d = Diagnostics::new();
        if self.platform.is_none() {
            d.push_hint(
                "platform",
                "PlatformParameters() missing",
                format!("known boards: {}", crate::accel::platform::board_names().join(", ")),
            );
        }
        if self.model.is_none() {
            d.push("model.computation", "GNN_Computation() missing");
        }
        if self.sampler.is_none() {
            d.push("sampler", "Sampler() missing");
        }
        if self.graph.is_none() {
            d.push("graph", "LoadInputGraph() missing");
        }
        match (self.platform, self.model, self.sampler, self.graph) {
            (Some(platform), Some(model), Some(sampler), Some(graph)) => Ok(ProgramSpec {
                platform,
                model: ModelSpec { computation: model, hidden: self.hidden },
                sampler,
                graph,
                seed: self.seed,
                layout: self.layout,
                placement: self.placement,
                training: self.training,
                serving: self.serving,
            }),
            _ => Err(d),
        }
    }

    /// `GenerateDesign()` — lower into a spec and [`ProgramSpec::build`]
    /// it.  `runtime` provides the artifact registry (the "bitstream
    /// library").
    pub fn generate_design(self, runtime: &Runtime) -> anyhow::Result<GeneratedDesign> {
        let spec = self.spec()?;
        spec.build(runtime)
    }
}

impl ProgramSpec {
    /// `GenerateDesign()` for a spec: full-pass validation, then DSE +
    /// artifact-geometry selection + sampler thread sizing.  Every
    /// validation problem is returned at once (as [`Diagnostics`] inside
    /// the error), not just the first.
    pub fn build(&self, runtime: &Runtime) -> anyhow::Result<GeneratedDesign> {
        self.validate().into_anyhow()?;
        let platform = self.platform.resolve()?;
        let (graph, full_rows) = self.graph.materialize(self.structure_seed())?;
        let model = self.model.computation;

        let feat = self.layer_dims(graph.feat_dim(), graph.num_classes());
        let batch = self.sampler.batch_geometry(graph.as_ref());
        let abstraction =
            GnnAbstraction { model, feat: feat.clone(), sampler: self.sampler.clone(), batch };

        // Hardware generator: Algorithm 4 on the target platform.
        let dse = explore(
            &platform,
            &DseProblem {
                geom: abstraction.batch.clone(),
                model: ModelShape { feat, sage_concat: model == GnnModel::Sage },
                layout: self.layout,
                coeff: ResourceCoefficients::default(),
                t_sampling_single: None,
            },
        );

        // Software generator: pick the smallest artifact geometry whose
        // bounds cover the sampler's worst case.
        let geometry = select_geometry(runtime, model, &abstraction)?;

        // DistributeData(): features go to FPGA DDR when the *full-scale*
        // matrix fits (paper §3.1), else stay in host memory and stream.
        let feature_bytes = full_rows * graph.feat_dim() * 4;
        let placement = self.placement.unwrap_or(if feature_bytes <= platform.ddr_bytes {
            FeaturePlacement::FpgaLocal
        } else {
            FeaturePlacement::HostStreamed
        });

        Ok(GeneratedDesign {
            platform,
            accel: dse,
            geometry,
            layout: self.layout,
            placement,
            graph: DynamicGraph::fixed(graph),
            abstraction,
            seed: self.resolved_seed(),
            spec: self.clone(),
        })
    }

    /// The per-layer feature dims `[f0, hidden..., classes]` — the one
    /// assembly [`build`](Self::build), [`design_check`](Self::design_check)
    /// and [`dse_problem`](Self::dse_problem) all share.
    fn layer_dims(&self, f0: usize, classes: usize) -> Vec<usize> {
        let mut feat = vec![f0];
        feat.extend(&self.model.hidden);
        feat.push(classes);
        feat
    }

    /// Statistics of this spec's graph — `(nodes, edges, feat_dim,
    /// num_classes)` — without instantiating a dataset graph (edge-list
    /// and inline graphs load / are already in memory).  `full_scale`
    /// picks the published Table 4 size (what DSE targets) over the
    /// spec's scaled size (what training materializes).
    fn graph_stats(&self, full_scale: bool) -> anyhow::Result<(usize, usize, usize, usize)> {
        match &self.graph {
            GraphSpec::Dataset { key, scale, .. } => {
                let ds = datasets::by_key(key)
                    .ok_or_else(|| anyhow::anyhow!("unknown dataset {key:?}"))?;
                if full_scale {
                    Ok((ds.nodes, ds.edges, ds.f0, ds.f2))
                } else {
                    let scaled = ds.scale(*scale);
                    Ok((scaled.nodes, scaled.edges, ds.f0, ds.f2))
                }
            }
            other => {
                let (g, _) = other.materialize(self.structure_seed())?;
                Ok((g.num_vertices(), g.num_edges(), g.feat_dim(), g.num_classes()))
            }
        }
    }

    /// The feasibility half of [`build`](Self::build) — full-pass
    /// validation, board resolution and artifact-geometry selection —
    /// sized from dataset *statistics*, so `hp-gnn validate` on a
    /// full-scale AmazonProducts program never instantiates 132M edges.
    /// Returns the geometry name [`build`](Self::build) would select (for
    /// dataset graphs the choice can differ only when the min-degree
    /// floor perturbs the subgraph/layerwise κ estimate).
    pub fn design_check(&self, runtime: &Runtime) -> anyhow::Result<String> {
        self.validate().into_anyhow()?;
        self.platform.resolve()?;
        let (nodes, edges, f0, classes) = self.graph_stats(false)?;
        let abstraction = GnnAbstraction {
            model: self.model.computation,
            feat: self.layer_dims(f0, classes),
            sampler: self.sampler.clone(),
            batch: self.sampler.batch_geometry_stats(nodes, edges),
        };
        select_geometry(runtime, self.model.computation, &abstraction)
    }

    /// The DSE problem this spec poses, sized against the graph's *full
    /// published statistics* (a `dataset` graph is never materialized —
    /// `hp-gnn dse` on AmazonProducts must not instantiate 132M edges;
    /// edge-list and inline graphs use their real size).
    pub fn dse_problem(&self) -> anyhow::Result<(Platform, DseProblem)> {
        self.validate().into_anyhow()?;
        let platform = self.platform.resolve()?;
        let (nodes, edges, f0, classes) = self.graph_stats(true)?;
        Ok((
            platform,
            DseProblem {
                geom: self.sampler.batch_geometry_stats(nodes, edges),
                model: ModelShape {
                    feat: self.layer_dims(f0, classes),
                    sage_concat: self.model.computation == GnnModel::Sage,
                },
                layout: self.layout,
                coeff: ResourceCoefficients::default(),
                t_sampling_single: None,
            },
        ))
    }
}

/// Pick an artifact geometry for the abstraction (smallest that fits).
fn select_geometry(
    runtime: &Runtime,
    model: GnnModel,
    abs: &GnnAbstraction,
) -> anyhow::Result<String> {
    let sampler = abs.sampler.build();
    let mut candidates: Vec<&crate::runtime::ArtifactSpec> = Vec::new();
    for name in runtime.manifest.names() {
        let spec = runtime.manifest.get(name)?;
        if spec.model.as_str() != model.artifact_key() || spec.kind != Kind::TrainStep {
            continue;
        }
        let geom = &spec.geometry;
        if geom.layers() != sampler.num_layers() || geom.f != abs.feat {
            continue;
        }
        // Vertex bounds must hold; edge overflow is tolerable only for
        // subgraph batches (truncation policy).
        let fits_b = abs.batch.b.iter().zip(&geom.b).all(|(need, have)| need <= have);
        let fits_e = match abs.sampler {
            SamplerSpec::Neighbor { .. } => {
                abs.batch.e.iter().zip(&geom.e).all(|(need, have)| need <= have)
            }
            _ => true,
        };
        if fits_b && fits_e {
            candidates.push(spec);
        }
    }
    // Prefer geometries whose shape class matches the sampler (NS batches
    // shrink per layer; SS batches keep b constant), then the smallest.
    let want_equal = !matches!(abs.sampler, SamplerSpec::Neighbor { .. });
    candidates.sort_by_key(|s| {
        let b = &s.geometry.b;
        let is_equal = b.windows(2).all(|w| w[0] == w[1]);
        (usize::from(is_equal != want_equal), s.geometry.total_vertices())
    });
    candidates
        .first()
        .map(|s| s.geometry.name.clone())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no artifact geometry fits model={} layers={} feat={:?} batch b={:?} — \
                 add a geometry to python/compile/geometry.py and `make artifacts`",
                model.as_str(),
                sampler.num_layers(),
                abs.feat,
                abs.batch.b,
            )
        })
}

/// Output of `GenerateDesign()`: everything needed to run training, plus
/// the originating [`ProgramSpec`] so an emitted design is rerunnable.
///
/// The graph is held as an `Arc<DynamicGraph>` so each
/// [`session`](Self::session) shares it with the producer threads instead
/// of deep-copying it (the feature matrix alone can be hundreds of MB at
/// full dataset scale), and so a [`server`](Self::server) can accept
/// edge-stream ingest: sessions and servers pin immutable
/// [snapshots](crate::graph::store::GraphSnapshot) while the dynamic
/// wrapper versions forward.
#[derive(Debug)]
pub struct GeneratedDesign {
    pub platform: Platform,
    pub accel: DseResult,
    pub geometry: String,
    pub layout: LayoutOptions,
    pub placement: FeaturePlacement,
    pub graph: Arc<DynamicGraph>,
    pub abstraction: GnnAbstraction,
    /// The resolved training/feature seed ([`ProgramSpec::resolved_seed`]).
    pub seed: u64,
    /// The program this design was generated from (single source of
    /// truth; [`to_json`](Self::to_json) embeds it so the emitted design
    /// doubles as a rerunnable experiment file).
    pub spec: ProgramSpec,
}

impl GeneratedDesign {
    /// The DSE-sized sampler thread pool (fallback 2 when the DSE engine
    /// had no sampling-time measurement) — the one number both
    /// [`train_config`](Self::train_config) and [`explain`](Self::explain)
    /// report.
    pub fn sampler_threads(&self) -> usize {
        self.accel.sampler_threads.unwrap_or(2)
    }

    /// The [`TrainConfig`] this design trains with (the generated host
    /// program's knobs): artifact geometry, DSE-sized sampler thread pool,
    /// overflow policy matched to the sampler class.
    pub fn train_config(&self, steps: usize, lr: f32, simulate: bool) -> TrainConfig {
        TrainConfig {
            model: self.abstraction.model,
            optimizer: Default::default(),
            geometry: self.geometry.clone(),
            steps,
            lr,
            seed: self.seed,
            layout: self.layout,
            sampler_threads: self.sampler_threads(),
            compute_threads: crate::util::threadpool::default_threads(),
            overflow: match self.abstraction.sampler {
                SamplerSpec::Neighbor { .. } => EdgeOverflow::Error,
                _ => EdgeOverflow::TruncateKeepSelf,
            },
            simulate: simulate.then(|| (self.platform.clone(), self.accel.config)),
            log_every: 0,
            value_fn: None,
        }
    }

    /// Open a [`TrainingSession`] on this design: compiles the artifact,
    /// spawns the producer pipeline, and hands back pull-based control
    /// (`step`/`run_for`/`evaluate`/`save`/`finish` plus the
    /// `on_step`/`on_eval` hooks).
    pub fn session<'rt>(
        &self,
        runtime: &'rt Runtime,
        lr: f32,
        simulate: bool,
    ) -> anyhow::Result<TrainingSession<'rt>> {
        TrainingSession::new(
            runtime,
            self.graph.snapshot() as Arc<dyn GraphAccess>,
            Arc::from(self.abstraction.sampler.build()),
            self.train_config(0, lr, simulate),
        )
    }

    /// [`session`](Self::session) restored from an `HPGNNS01` snapshot:
    /// weights, optimizer state and the RNG cursor come from `checkpoint`,
    /// and training continues bit-exactly where the snapshotted run left
    /// off (reference backend).
    pub fn resume_session<'rt>(
        &self,
        runtime: &'rt Runtime,
        lr: f32,
        simulate: bool,
        checkpoint: &Path,
    ) -> anyhow::Result<TrainingSession<'rt>> {
        TrainingSession::resume(
            runtime,
            self.graph.snapshot() as Arc<dyn GraphAccess>,
            Arc::from(self.abstraction.sampler.build()),
            self.train_config(0, lr, simulate),
            checkpoint,
        )
    }

    /// Serving configuration for this design: the training-time model,
    /// artifact geometry, layout, overflow policy and seed, overlaid with
    /// the spec's `serving` section when present (defaults otherwise) —
    /// override fields before handing it to [`server`](Self::server).
    pub fn serve_config(&self) -> ServeConfig {
        let cfg = ServeConfig::from_train(&self.train_config(0, 0.0, false));
        match &self.spec.serving {
            Some(serving) => cfg.apply_spec(serving),
            None => cfg,
        }
    }

    /// Open an inference [`Server`] on this design from a trained
    /// checkpoint (either `HPGNNW01` weights or an `HPGNNS01` session
    /// snapshot): compiles one forward executor replica per worker,
    /// spawns the micro-batcher + worker pool, and answers
    /// [`classify`](Server::classify) requests until shutdown.
    pub fn server(
        &self,
        runtime: &Runtime,
        cfg: ServeConfig,
        checkpoint: &Path,
    ) -> anyhow::Result<Server> {
        Server::from_checkpoint(
            runtime,
            Arc::clone(&self.graph),
            Arc::from(self.abstraction.sampler.build()),
            cfg,
            checkpoint,
        )
    }

    /// `Start_training()` — run Algorithm 2 for `steps` iterations (the
    /// paper's fire-and-forget host program: a session driven start to
    /// finish in one call).
    pub fn start_training(
        &self,
        runtime: &Runtime,
        steps: usize,
        lr: f32,
        simulate: bool,
    ) -> anyhow::Result<TrainReport> {
        let mut session = self.session(runtime, lr, simulate)?;
        session.run_for(steps)?;
        Ok(session.finish())
    }

    /// The Listing-3 generated-design report: chosen artifact geometry,
    /// DSE configuration, predicted throughput, resource utilization and
    /// feature placement, as human-readable text (`hp-gnn explain`).
    pub fn explain(&self) -> String {
        let u = &self.accel.utilization;
        let mut out = String::new();
        out.push_str("== generated design ==\n");
        out.push_str(&format!(
            "platform:        {} ({} dies, {} DSP/die, {:.1} GB/s)\n",
            self.platform.name,
            self.platform.dies,
            self.platform.dsp_per_die,
            self.platform.total_bw_gbps()
        ));
        out.push_str(&format!(
            "model:           {}, layer dims {:?}\n",
            self.abstraction.model.as_str(),
            self.abstraction.feat
        ));
        out.push_str(&format!("sampler:         {}\n", self.abstraction.sampler.describe()));
        let graph_name = self.graph.name();
        out.push_str(&format!(
            "graph:           {} ({} vertices, {} edges)\n",
            if graph_name.is_empty() { "<unnamed>" } else { &graph_name },
            self.graph.num_vertices(),
            self.graph.num_edges()
        ));
        out.push_str(&format!(
            "seed:            {} (training/features; structure seed {})\n",
            self.seed,
            self.spec.structure_seed()
        ));
        out.push_str(&format!(
            "layout:          RMT {}, RRA {}\n",
            if self.layout.rmt { "on" } else { "off" },
            if self.layout.rra { "on" } else { "off" }
        ));
        out.push_str(&format!(
            "artifact:        {} (batch needs b={:?}, e={:?})\n",
            self.geometry, self.abstraction.batch.b, self.abstraction.batch.e
        ));
        out.push_str(&format!(
            "accelerator:     (m, n) = ({}, {}) per die -> predicted {} NVTPS \
             ({} candidates explored)\n",
            self.accel.config.m,
            self.accel.config.n,
            crate::util::si(self.accel.nvtps),
            self.accel.evaluated
        ));
        out.push_str(&format!(
            "utilization:     DSP {:.0}%  LUT {:.0}%  URAM {:.0}%  BRAM {:.0}%\n",
            u.dsp * 100.0,
            u.lut * 100.0,
            u.uram * 100.0,
            u.bram * 100.0
        ));
        out.push_str(&format!(
            "placement:       {}\n",
            match self.placement {
                FeaturePlacement::FpgaLocal => "fpga-local",
                FeaturePlacement::HostStreamed => "host-streamed",
            }
        ));
        out.push_str(&format!("sampler threads: {}", self.sampler_threads()));
        out
    }

    /// The generated design as JSON: a `"program"` section holding the
    /// round-trippable [`ProgramSpec`] (re-runnable with `hp-gnn run`;
    /// `null` for the two builder-only escape hatches) and a `"design"`
    /// section summarizing what the generators chose.
    pub fn to_json(&self) -> Json {
        let design = Json::obj(vec![
            ("board", Json::str(self.platform.name.clone())),
            ("model", Json::str(self.abstraction.model.as_str())),
            (
                "feat_dims",
                Json::arr(self.abstraction.feat.iter().map(|&f| Json::num(f as f64)).collect()),
            ),
            ("artifact_geometry", Json::str(self.geometry.clone())),
            (
                "feature_placement",
                Json::str(match self.placement {
                    FeaturePlacement::FpgaLocal => "fpga-local",
                    FeaturePlacement::HostStreamed => "host-streamed",
                }),
            ),
            ("accel_n_scatter_pes", Json::num(self.accel.config.n as f64)),
            ("accel_m_macs", Json::num(self.accel.config.m as f64)),
            ("predicted_nvtps", Json::num(self.accel.nvtps)),
            ("dsp_utilization", Json::num(self.accel.utilization.dsp)),
            ("lut_utilization", Json::num(self.accel.utilization.lut)),
            ("uram_utilization", Json::num(self.accel.utilization.uram)),
            ("bram_utilization", Json::num(self.accel.utilization.bram)),
            (
                "batch_b",
                Json::arr(self.abstraction.batch.b.iter().map(|&b| Json::num(b as f64)).collect()),
            ),
            (
                "batch_e",
                Json::arr(self.abstraction.batch.e.iter().map(|&e| Json::num(e as f64)).collect()),
            ),
        ]);
        Json::obj(vec![
            ("program", self.spec.to_json().unwrap_or(Json::Null)),
            ("design", design),
        ])
    }
}

/// The runtime-owning facade: open once, then design/train/serve without
/// threading `&Runtime` through every call.
///
/// ```no_run
/// # use hp_gnn::api::{ProgramSpec, Workspace};
/// # fn demo(spec: &ProgramSpec) -> anyhow::Result<()> {
/// let design = Workspace::open(std::path::Path::new("artifacts"))?.design(spec)?;
/// design.session()?.run_for(10)?;
/// # Ok(()) }
/// ```
pub struct Workspace {
    runtime: Arc<Runtime>,
}

impl Workspace {
    /// Open over an artifact directory ([`Runtime::auto`]: a real manifest
    /// when one exists, the built-in reference catalog otherwise).
    pub fn open(artifacts: &Path) -> anyhow::Result<Workspace> {
        Ok(Workspace { runtime: Arc::new(Runtime::auto(artifacts)?) })
    }

    /// The artifact-less reference-backend workspace.
    pub fn reference() -> Workspace {
        Workspace { runtime: Arc::new(Runtime::reference()) }
    }

    /// Wrap an already-constructed runtime.
    pub fn with_runtime(runtime: Runtime) -> Workspace {
        Workspace { runtime: Arc::new(runtime) }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// `GenerateDesign()` — [`ProgramSpec::build`] against this
    /// workspace's runtime, returning a [`Design`] whose
    /// `session()`/`server()`/`explain()` need no further `&Runtime`.
    pub fn design(&self, spec: &ProgramSpec) -> anyhow::Result<Design> {
        Ok(Design { inner: spec.build(&self.runtime)?, runtime: Arc::clone(&self.runtime) })
    }
}

/// A [`GeneratedDesign`] bound to the [`Workspace`]'s runtime.  Derefs to
/// the design, so every `GeneratedDesign` accessor works here too.
pub struct Design {
    runtime: Arc<Runtime>,
    inner: GeneratedDesign,
}

impl std::ops::Deref for Design {
    type Target = GeneratedDesign;
    fn deref(&self) -> &GeneratedDesign {
        &self.inner
    }
}

impl Design {
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Unwrap the bare [`GeneratedDesign`].
    pub fn into_inner(self) -> GeneratedDesign {
        self.inner
    }

    /// A [`TrainingSession`] with the spec's `training.lr` / `simulate`.
    pub fn session(&self) -> anyhow::Result<TrainingSession<'_>> {
        self.session_with(self.inner.spec.training.lr, self.inner.spec.training.simulate)
    }

    /// [`session`](Self::session) with explicit overrides.
    pub fn session_with(&self, lr: f32, simulate: bool) -> anyhow::Result<TrainingSession<'_>> {
        self.inner.session(&self.runtime, lr, simulate)
    }

    /// A session on a caller-tuned [`TrainConfig`] (start from
    /// [`GeneratedDesign::train_config`]).
    pub fn session_with_config(&self, cfg: TrainConfig) -> anyhow::Result<TrainingSession<'_>> {
        TrainingSession::new(
            &self.runtime,
            self.inner.graph.snapshot() as Arc<dyn GraphAccess>,
            Arc::from(self.inner.abstraction.sampler.build()),
            cfg,
        )
    }

    /// A session resumed from an `HPGNNS01` snapshot, with the spec's
    /// `training.lr` / `simulate`.
    pub fn resume_session(&self, checkpoint: &Path) -> anyhow::Result<TrainingSession<'_>> {
        self.inner.resume_session(
            &self.runtime,
            self.inner.spec.training.lr,
            self.inner.spec.training.simulate,
            checkpoint,
        )
    }

    /// [`resume_session`](Self::resume_session) on a caller-tuned config.
    pub fn resume_session_with_config(
        &self,
        cfg: TrainConfig,
        checkpoint: &Path,
    ) -> anyhow::Result<TrainingSession<'_>> {
        TrainingSession::resume(
            &self.runtime,
            self.inner.graph.snapshot() as Arc<dyn GraphAccess>,
            Arc::from(self.inner.abstraction.sampler.build()),
            cfg,
            checkpoint,
        )
    }

    /// An inference [`Server`] configured entirely by the spec's `serving`
    /// section (which must name a `checkpoint`).
    pub fn server(&self) -> anyhow::Result<Server> {
        let serving = self.inner.spec.serving.clone().unwrap_or_default();
        let checkpoint = serving.checkpoint.clone().ok_or_else(|| {
            anyhow::anyhow!(
                "the program names no trained checkpoint to serve — add \
                 serving.checkpoint, or use server_from(path)"
            )
        })?;
        self.server_from(&checkpoint)
    }

    /// A server from an explicit checkpoint, serving knobs from the
    /// spec's `serving` section (defaults when absent).
    pub fn server_from(&self, checkpoint: &Path) -> anyhow::Result<Server> {
        self.server_with(self.inner.serve_config(), checkpoint)
    }

    /// A server on a caller-tuned [`ServeConfig`].
    pub fn server_with(&self, cfg: ServeConfig, checkpoint: &Path) -> anyhow::Result<Server> {
        self.inner.server(&self.runtime, cfg, checkpoint)
    }

    /// `Start_training()` — run the spec's `training.steps` to completion.
    pub fn start_training(&self) -> anyhow::Result<TrainReport> {
        let t = &self.inner.spec.training;
        self.inner.start_training(&self.runtime, t.steps, t.lr, t.simulate)
    }

    /// The Listing-3 report ([`GeneratedDesign::explain`]).
    pub fn explain(&self) -> String {
        self.inner.explain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_spec_builds_and_sizes() {
        let g = crate::graph::generator::uniform(1000, 8000, true, 1);
        let ns = SamplerSpec::Neighbor { targets: 16, budgets: vec![5, 3] };
        assert_eq!(ns.layers(), 2);
        let geom = ns.batch_geometry(&g);
        assert_eq!(geom.b[2], 16);
        assert!(geom.b[0] > geom.b[1]);
        let ss = SamplerSpec::Subgraph { budget: 100, layers: 2 };
        let geom = ss.batch_geometry(&g);
        assert_eq!(geom.b, vec![100, 100, 100]);
        let s = ns.build();
        assert_eq!(s.num_layers(), 2);
        // The stats-based variant agrees with the graph-based one.
        assert_eq!(
            ns.batch_geometry_stats(g.num_vertices(), g.num_edges()).b,
            ns.batch_geometry(&g).b
        );
    }

    /// An artifact-less runtime on the always-available reference backend
    /// (these tests only exercise builder validation).
    fn empty_runtime() -> Runtime {
        Runtime::with_backend(
            crate::runtime::Manifest::from_specs(Vec::new()).unwrap(),
            Box::new(crate::runtime::ReferenceBackend::default()),
        )
    }

    #[test]
    fn builder_validates_missing_pieces() {
        let rt = empty_runtime();
        let err = HpGnn::init().generate_design(&rt).unwrap_err().to_string();
        // Every missing Table 1 call is reported at once, by paper name.
        assert!(err.contains("PlatformParameters"), "{err}");
        assert!(err.contains("GNN_Computation"), "{err}");
        assert!(err.contains("Sampler"), "{err}");
        assert!(err.contains("LoadInputGraph"), "{err}");
        let err = HpGnn::init()
            .platform(Platform::alveo_u250())
            .generate_design(&rt)
            .unwrap_err()
            .to_string();
        assert!(err.contains("GNN_Computation"), "{err}");
        assert!(!err.contains("PlatformParameters"), "{err}");
    }

    #[test]
    fn unknown_board_rejected_with_registry_listing() {
        let err = HpGnn::init().platform_board("stratix-10").unwrap_err().to_string();
        assert!(err.contains("stratix-10"), "{err}");
        assert!(err.contains("xilinx-U250") && err.contains("xilinx-U280"), "{err}");
        assert!(HpGnn::init().platform_board("Xilinx-U250").is_ok());
        assert!(HpGnn::init().platform_board("xilinx-u280").is_ok());
    }

    #[test]
    fn hidden_dims_must_match_depth() {
        let rt = empty_runtime();
        let mut g = crate::graph::generator::uniform(100, 500, true, 2);
        g.feat_dim = 16;
        g.num_classes = 4;
        let err = HpGnn::init()
            .platform(Platform::alveo_u250())
            .gnn_computation("gcn")
            .unwrap()
            .gnn_parameters(vec![8, 8]) // 2 hidden for 2 layers: wrong
            .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![3, 3] })
            .load_input_graph(g)
            .generate_design(&rt)
            .unwrap_err()
            .to_string();
        assert!(err.contains("model.hidden"), "{err}");
        assert!(err.contains("GNN_Parameters"), "{err}");
    }

    #[test]
    fn builder_lowers_into_a_serializable_spec() {
        let spec = HpGnn::init()
            .platform_board("xilinx-U250")
            .unwrap()
            .gnn_computation("GCN")
            .unwrap()
            .gnn_parameters(vec![8])
            .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] })
            .seed(7)
            .load_dataset("FL", 0.005, 7)
            .unwrap()
            .serving(ServingSpec { workers: 3, ..Default::default() })
            .spec()
            .unwrap();
        assert!(spec.validate().is_empty());
        assert_eq!(spec.resolved_seed(), 7);
        let text = spec.to_json().unwrap().pretty();
        let again = ProgramSpec::from_json(&text).unwrap();
        assert_eq!(again, spec);
        assert_eq!(again.serving.as_ref().unwrap().workers, 3);
    }

    #[test]
    fn workspace_designs_and_opens_sessions() {
        let ws = Workspace::reference();
        let mut g = crate::graph::generator::with_min_degree(
            crate::graph::generator::rmat(400, 3200, Default::default(), 5),
            1,
            6,
        );
        g.feat_dim = 16;
        g.num_classes = 4;
        let spec = HpGnn::init()
            .platform_board("xilinx-U250")
            .unwrap()
            .gnn_computation("gcn")
            .unwrap()
            .gnn_parameters(vec![8])
            .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] })
            .load_input_graph(g)
            .training(TrainingSpec { steps: 2, lr: 0.1, ..Default::default() })
            .spec()
            .unwrap();
        let design = ws.design(&spec).unwrap();
        // Deref exposes the GeneratedDesign fields...
        assert_eq!(design.abstraction.model, GnnModel::Gcn);
        assert_eq!(design.seed, 1, "no seed given -> default 1");
        // ...explain() renders the Listing-3 report...
        let report = design.explain();
        assert!(report.contains("artifact:"), "{report}");
        assert!(report.contains("utilization:"), "{report}");
        // ...and a session opens + steps without touching the runtime.
        let mut session = design.session().unwrap();
        session.run_for(2).unwrap();
        assert_eq!(session.current_step(), 2);
        // Inline graphs have no JSON form: design JSON says so.
        let json = design.to_json();
        assert_eq!(*json.get("program").unwrap(), Json::Null);
        assert!(json.get("design").unwrap().get("artifact_geometry").is_ok());
    }
}
