//! Structured, full-pass program diagnostics.
//!
//! The old validation style (`anyhow::bail!` at the first problem) made
//! fixing a user program a whack-a-mole loop: fix one field, re-run, hit
//! the next error.  [`Diagnostics`] is the replacement contract: every
//! checker walks the *whole* spec and reports *all* problems at once, each
//! as a [`Diagnostic`] anchored to the JSON path it concerns
//! (`"sampler.budgets"`, `"model.hidden"`, …) with an optional fix hint.
//!
//! `Diagnostics` implements [`std::error::Error`], so a non-empty set
//! converts into `anyhow::Error` losslessly — its `Display` renders the
//! complete list, which is what `hp-gnn validate` prints line by line.

use std::fmt;

/// One problem in a user program, anchored to the spec path it concerns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Dotted JSON path of the offending field (`"sampler.budgets"`), or a
    /// section name when the problem is section-level (`"graph"`); `"$"`
    /// means the document itself did not parse.
    pub path: String,
    /// What is wrong with the value at `path`.
    pub reason: String,
    /// How to fix it, when a concrete suggestion exists.
    pub hint: Option<String>,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.path, self.reason)?;
        if let Some(hint) = &self.hint {
            write!(f, " (hint: {hint})")?;
        }
        Ok(())
    }
}

/// An ordered collection of [`Diagnostic`]s — the result of one full
/// validation pass.  Empty means the program is clean.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Diagnostics {
    items: Vec<Diagnostic>,
}

impl Diagnostics {
    pub fn new() -> Diagnostics {
        Diagnostics::default()
    }

    /// A single-entry set (e.g. "the document is not JSON at all").
    pub fn one(path: impl Into<String>, reason: impl Into<String>) -> Diagnostics {
        let mut d = Diagnostics::new();
        d.push(path, reason);
        d
    }

    /// Record a problem without a fix hint.
    pub fn push(&mut self, path: impl Into<String>, reason: impl Into<String>) {
        self.items.push(Diagnostic { path: path.into(), reason: reason.into(), hint: None });
    }

    /// Record a problem with a concrete fix hint.
    pub fn push_hint(
        &mut self,
        path: impl Into<String>,
        reason: impl Into<String>,
        hint: impl Into<String>,
    ) {
        self.items.push(Diagnostic {
            path: path.into(),
            reason: reason.into(),
            hint: Some(hint.into()),
        });
    }

    /// Append every entry of `other` (checkers compose by merging).
    pub fn merge(&mut self, other: Diagnostics) {
        self.items.extend(other.items);
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Diagnostic> {
        self.items.iter()
    }

    /// `Ok(value)` when clean, `Err(self)` when any problem was recorded.
    pub fn into_result<T>(self, value: T) -> Result<T, Diagnostics> {
        if self.is_empty() {
            Ok(value)
        } else {
            Err(self)
        }
    }

    /// `Ok(())` when clean, `Err(anyhow)` carrying the full list otherwise.
    pub fn into_anyhow(self) -> anyhow::Result<()> {
        if self.is_empty() {
            Ok(())
        } else {
            Err(anyhow::Error::new(self))
        }
    }
}

impl fmt::Display for Diagnostics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "invalid program: {} problem{}",
            self.items.len(),
            if self.items.len() == 1 { "" } else { "s" }
        )?;
        for (i, item) in self.items.iter().enumerate() {
            if i + 1 == self.items.len() {
                write!(f, "  - {item}")?;
            } else {
                writeln!(f, "  - {item}")?;
            }
        }
        Ok(())
    }
}

impl std::error::Error for Diagnostics {}

impl IntoIterator for Diagnostics {
    type Item = Diagnostic;
    type IntoIter = std::vec::IntoIter<Diagnostic>;
    fn into_iter(self) -> Self::IntoIter {
        self.items.into_iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_lists_every_problem_with_paths() {
        let mut d = Diagnostics::new();
        d.push("sampler.budgets", "must not be empty");
        d.push_hint("platform", "unknown board \"x\"", "known boards: xilinx-U250");
        let text = d.to_string();
        assert!(text.contains("2 problems"), "{text}");
        assert!(text.contains("sampler.budgets: must not be empty"), "{text}");
        assert!(text.contains("platform: unknown board"), "{text}");
        assert!(text.contains("hint: known boards"), "{text}");
    }

    #[test]
    fn into_result_and_anyhow_respect_emptiness() {
        assert_eq!(Diagnostics::new().into_result(7).unwrap(), 7);
        assert!(Diagnostics::new().into_anyhow().is_ok());
        let d = Diagnostics::one("graph", "missing section");
        assert!(d.clone().into_result(0).is_err());
        let err = d.into_anyhow().unwrap_err().to_string();
        assert!(err.contains("graph: missing section"), "{err}");
    }

    #[test]
    fn merge_preserves_order() {
        let mut a = Diagnostics::one("a", "first");
        a.merge(Diagnostics::one("b", "second"));
        let paths: Vec<&str> = a.iter().map(|x| x.path.as_str()).collect();
        assert_eq!(paths, vec!["a", "b"]);
    }
}
