"""AOT export: lower L2 train/eval functions to HLO text + manifest.

This is the framework's ``GenerateDesign()`` (paper Table 1): it plays the
role Vitis HLS synthesis plays in HP-GNN — turning the operator templates,
filled with the selected model's Aggregate/Update computation, into a fixed
executable per mini-batch geometry.  The rust runtime compiles each HLO
module once on the PJRT CPU client and runs it on every training iteration;
Python never executes on the training path.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out ../artifacts [--only tiny]
"""

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import geometry, model

# (geometry, export train_step?, export forward?)
EXPORT_GEOMETRIES = ("tiny", "ns_small", "ss_small", "ns_medium")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_str(dt) -> str:
    return {"float32": "f32", "int32": "i32", "float64": "f64", "int64": "i64"}[
        str(dt)
    ]


def _spec_list(specs):
    return [
        {"name": name, "shape": list(s.shape), "dtype": _dtype_str(s.dtype)}
        for name, s in specs
    ]


def export_one(mdl: str, geom_name: str, kind: str, out_dir: str) -> dict:
    """Lower one (model, geometry, kind) and write its .hlo.txt."""
    geom = geometry.get(geom_name)
    with_lr = kind in ("train_step", "adam_step")
    if kind == "train_step":
        fn = model.make_train_step_fn(mdl, geom)
    elif kind == "adam_step":
        fn = model.make_adam_train_step_fn(mdl, geom)
    elif kind == "forward":
        fn = model.make_forward_fn(mdl, geom)
    else:
        raise ValueError(f"unknown artifact kind {kind!r}")

    specs = model.example_args(mdl, geom, with_lr=with_lr)
    if kind == "adam_step":
        # Adam state trails the base ABI: m_i, v_i per weight tensor, then
        # the step counter.
        import jax.numpy as jnp
        import jax as _jax

        extra = []
        for l, (wshape, bshape) in enumerate(model.weight_shapes(mdl, geom), start=1):
            extra.append((f"m_w{l}", _jax.ShapeDtypeStruct(tuple(wshape), jnp.float32)))
            extra.append((f"m_b{l}", _jax.ShapeDtypeStruct(tuple(bshape), jnp.float32)))
        for l, (wshape, bshape) in enumerate(model.weight_shapes(mdl, geom), start=1):
            extra.append((f"v_w{l}", _jax.ShapeDtypeStruct(tuple(wshape), jnp.float32)))
            extra.append((f"v_b{l}", _jax.ShapeDtypeStruct(tuple(bshape), jnp.float32)))
        extra.append(("step", _jax.ShapeDtypeStruct((), jnp.float32)))
        specs = specs + extra
    t0 = time.time()
    # keep_unused: the rust ABI passes every manifest input positionally;
    # without it jit prunes e.g. labels/mask from forward-only exports.
    lowered = jax.jit(fn, keep_unused=True).lower(*[s for _, s in specs])
    text = to_hlo_text(lowered)
    name = f"{mdl}_{geom_name}_{kind}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    ll = geom.layers
    if kind == "train_step":
        outputs = ["loss"]
        for l in range(1, ll + 1):
            outputs += [f"w{l}", f"b{l}"]
    elif kind == "adam_step":
        outputs = ["loss"]
        for l in range(1, ll + 1):
            outputs += [f"w{l}", f"b{l}"]
        for l in range(1, ll + 1):
            outputs += [f"m_w{l}", f"m_b{l}"]
        for l in range(1, ll + 1):
            outputs += [f"v_w{l}", f"v_b{l}"]
        outputs += ["step"]
    else:
        outputs = ["logits"]
    entry = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "model": mdl,
        "geometry": geom_name,
        "kind": kind,
        "inputs": _spec_list(specs),
        "outputs": outputs,
        "weight_shapes": [
            {"w": list(ws), "b": list(bs)} for ws, bs in model.weight_shapes(mdl, geom)
        ],
        "geometry_spec": {
            "b": list(geom.b),
            "e": list(geom.e),
            "f": list(geom.f),
            "layers": ll,
            "num_classes": geom.num_classes,
        },
    }
    print(
        f"  {name}: {len(text) / 1024:.0f} KiB HLO, "
        f"{len(specs)} inputs, {time.time() - t0:.1f}s"
    )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated geometry filter (default: all export geometries)",
    )
    ap.add_argument(
        "--models", default="gcn,sage", help="comma-separated model filter"
    )
    args = ap.parse_args()

    geoms = args.only.split(",") if args.only else list(EXPORT_GEOMETRIES)
    models = args.models.split(",")
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for g in geoms:
        for m in models:
            kinds = ["train_step", "forward"]
            # Adam variants for the geometries the coordinator trains on.
            if g in ("tiny", "ns_small", "ss_small"):
                kinds.append("adam_step")
            for kind in kinds:
                entries.append(export_one(m, g, kind, args.out))

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
