"""Static mini-batch geometries for AOT export.

PJRT executables have fixed shapes, so every (sampler, dataset-class) pair
is compiled against a *geometry*: per-layer padded vertex counts ``b[l]``,
padded edge counts ``e[l]``, and feature dims ``f[l]``.  This is exactly the
"mini-batch configuration" the paper's program parser deduces from the
sampling algorithm (Section 3.2): |B^l| and |E^l| per layer.

The rust coordinator pads real sampled mini-batches up to the geometry
(padding edges carry ``val = 0``; padding target vertices carry
``mask = 0``), so functional results are exact.

Paper-scale geometries (e.g. NS with |B^0| = 256000, f0 = 602) are
*simulator-only* — they never run through the CPU PJRT client; the
geometries below are the reduced functional-path classes (DESIGN.md §6).
"""

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class Geometry:
    """Fixed shapes of one compiled mini-batch class.

    Attributes:
      name:  registry key, also used in artifact file names.
      b:     ``(L+1,)`` padded vertex count per layer; ``b[0]`` is the input
             layer, ``b[L]`` the target vertices.
      e:     ``(L,)`` padded edge count per layer; ``e[l]`` connects layer
             ``l`` (1-based) to layer ``l-1``.
      f:     ``(L+1,)`` feature dims; ``f[0]`` input features, ``f[L]`` the
             number of classes.
    """

    name: str
    b: Tuple[int, ...]
    e: Tuple[int, ...]
    f: Tuple[int, ...]

    def __post_init__(self):
        if len(self.b) != len(self.f):
            raise ValueError("b and f must both have L+1 entries")
        if len(self.e) != len(self.b) - 1:
            raise ValueError("e must have L entries")
        for l in range(1, len(self.b)):
            if self.b[l] > self.b[l - 1]:
                raise ValueError(
                    f"layer {l}: b[{l}]={self.b[l]} exceeds b[{l-1}]={self.b[l-1]}; "
                    "samplers keep B^l a subset of B^(l-1) (self loops)"
                )

    @property
    def layers(self) -> int:
        return len(self.e)

    @property
    def num_classes(self) -> int:
        return self.f[-1]

    @property
    def total_vertices(self) -> int:
        """Numerator of the paper's NVTPS metric (Eq. 4) for one batch."""
        return sum(self.b)


# Registry.  NS = neighbor sampling (GraphSAGE sampler), SS = subgraph
# sampling (GraphSAINT node sampler).  Edge budgets include self loops:
# an NS layer needs b[l] * (ns_l + 1) edge slots.
# Worst-case NS bounds include the self vertex: expanding layer l with
# fan-out ns gives b[l-1] <= b[l] * (ns + 1) and e[l] = b[l] * (ns + 1).
GEOMETRIES = {
    # CI-scale geometry (NS targets=4, budgets=[5, 3]): every pytest /
    # cargo test integration path uses it.
    "tiny": Geometry("tiny", b=(96, 16, 4), e=(96, 16), f=(16, 8, 4)),
    # End-to-end driver: Flickr-class feature dims, NS budgets [5, 10] on 32
    # targets (reduced from the paper's [10, 25] x 1024 — see DESIGN.md §6).
    "ns_small": Geometry(
        "ns_small", b=(2112, 352, 32), e=(2112, 352), f=(500, 256, 7)
    ),
    # End-to-end driver for subgraph sampling: one subgraph, all layers share
    # the vertex set (B^0 = B^1 = B^2, paper §2.3).
    "ss_small": Geometry(
        "ss_small", b=(256, 256, 256), e=(2048, 2048), f=(500, 256, 7)
    ),
    # Larger NS class used by the perf pass on the functional path
    # (targets=128, budgets=[5, 10]).
    "ns_medium": Geometry(
        "ns_medium", b=(8448, 1408, 128), e=(8448, 1408), f=(500, 256, 7)
    ),
}


def get(name: str) -> Geometry:
    try:
        return GEOMETRIES[name]
    except KeyError:
        raise KeyError(
            f"unknown geometry {name!r}; known: {sorted(GEOMETRIES)}"
        ) from None
