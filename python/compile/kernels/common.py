"""Shared helpers for the HP-GNN Pallas kernels.

Block sizes mirror the paper's hardware granularity: the HLS aggregate
kernel routes 16-lane feature chunks through the butterfly network and the
update kernel is a 16x16-granular MAC array.  On TPU the natural granule is
the (8, 128) VREG / 128x128 MXU tile, so blocks here are multiples of 128
(see DESIGN.md §Hardware-Adaptation).
"""

import os

import jax.numpy as jnp

# Feature-dimension block processed per grid step by the aggregate kernel.
# One block of source features is a single HBM->VMEM copy; this plays the
# role of the paper's Feature Duplicator broadcast.
FEATURE_BLOCK = 128

# Update (matmul) kernel tile sizes — MXU-shaped.
TILE_M = 128
TILE_N = 128

# Edge-stream chunk per inner loop step in the aggregate kernel.
EDGE_BLOCK = 512

# All kernels run in interpret mode: the CPU PJRT client that the rust
# runtime drives cannot execute Mosaic custom-calls.  Set HP_GNN_NO_INTERPRET
# only when compiling for a real TPU backend.
INTERPRET = os.environ.get("HP_GNN_NO_INTERPRET", "") == ""


def ceil_to(x: int, m: int) -> int:
    """Round ``x`` up to a multiple of ``m`` (minimum one block)."""
    if x <= 0:
        return m
    return ((x + m - 1) // m) * m


def pad_axis(arr, axis: int, target: int, value=0):
    """Pad ``arr`` with ``value`` along ``axis`` up to length ``target``."""
    cur = arr.shape[axis]
    if cur == target:
        return arr
    if cur > target:
        raise ValueError(f"cannot pad axis {axis} of length {cur} down to {target}")
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(arr, widths, constant_values=value)
