"""Aggregate kernel — Pallas twin of the paper's Fig. 5 HLS template.

The FPGA aggregate kernel streams COO edges (sorted by source — the RMT
layout) through n Scatter PEs, routes ``val * feature`` updates through a
butterfly network, and accumulates them in Gather-PE on-chip banks indexed
by the RRA-renamed (dense, ascending) destination ids.

The TPU/Pallas rethink (DESIGN.md §Hardware-Adaptation): there is no
inter-PE routing network, so what survives is the *data layout contract* —
edges arrive renamed and sorted, destination ids are dense in
``[0, num_out)``, so a bounded VMEM accumulator (the output block) can hold
the gather state, and sequential in-kernel accumulation removes the RAW
hazard the FPGA resolves by stalling.  The grid walks feature blocks; each
grid step owns a ``(num_out, FEATURE_BLOCK)`` accumulator, which is the
Gather-PE result-bank analog.

Semantics (the paper's Algorithm 3 with Scatter = ``val * feat`` and
Gather = ``+=``)::

    out[v, :] = sum over edges e with dst[e] == v of  val[e] * x[src[e], :]

Padding contract: callers pad the edge stream with ``val == 0`` edges whose
``src``/``dst`` point at valid (padded) rows; zero-valued edges contribute
nothing, so padded results are exact.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import FEATURE_BLOCK, INTERPRET, ceil_to, pad_axis


def _aggregate_kernel(src_ref, dst_ref, val_ref, x_ref, o_ref):
    """One feature block: gather the edge stream, accumulate into o_ref.

    The whole edge stream is processed as one vectorized gather +
    segment-sum into the block's dense accumulator (the Gather-PE
    result-bank analog).  An earlier revision replayed edges one at a time
    with dynamic slices — hardware-shaped but ~300x slower through
    interpret-mode XLA (see EXPERIMENTS.md §Perf); the per-edge schedule
    lives on in the rust cycle simulator, which is the timing twin.
    """
    x = x_ref[...]
    src = src_ref[...]
    dst = dst_ref[...]
    val = val_ref[...]
    contrib = x[src] * val[:, None].astype(x.dtype)
    o_ref[...] = jax.ops.segment_sum(
        contrib, dst, num_segments=o_ref.shape[0]
    ).astype(o_ref.dtype)


def _aggregate_impl(x, src, dst, val, num_out: int):
    """Raw (non-differentiable) pallas_call wrapper."""
    num_in, feat = x.shape
    f_pad = ceil_to(feat, FEATURE_BLOCK)
    xp = pad_axis(x, 1, f_pad)
    val = val.astype(x.dtype)
    grid = (f_pad // FEATURE_BLOCK,)

    out = pl.pallas_call(
        _aggregate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(src.shape, lambda j: (0,)),
            pl.BlockSpec(dst.shape, lambda j: (0,)),
            pl.BlockSpec(val.shape, lambda j: (0,)),
            pl.BlockSpec((num_in, FEATURE_BLOCK), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((num_out, FEATURE_BLOCK), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((num_out, f_pad), x.dtype),
        interpret=INTERPRET,
    )(src, dst, val, xp)
    return out[:, :feat]


def aggregate_fwd_only(x, src, dst, val, num_out: int):
    """Aggregate without autodiff plumbing (inference-only exports)."""
    return _aggregate_impl(x, src, dst, val, num_out)


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def aggregate(x, src, dst, val, num_out: int):
    """Differentiable weighted neighbor aggregation over a COO edge stream.

    Args:
      x:   ``(num_in, f)`` source feature matrix (h^{l-1}).
      src: ``(E,)`` int32 source indices into ``x`` (RMT-sorted).
      dst: ``(E,)`` int32 destination indices in ``[0, num_out)``
           (RRA-renamed, dense).
      val: ``(E,)`` edge values (GCN normalization, SAGE 1/(deg+1) means,
           or learnable weights).
      num_out: static number of output rows (|B^l|).

    Returns:
      ``(num_out, f)`` aggregated features a^l.
    """
    return _aggregate_impl(x, src, dst, val, num_out)


def _aggregate_fwd(x, src, dst, val, num_out: int):
    y = _aggregate_impl(x, src, dst, val, num_out)
    return y, (x, src, dst, val)


def _aggregate_bwd(num_out: int, res, g):
    x, src, dst, val = res
    g = g.astype(x.dtype)
    # The backward aggregation is the forward kernel on the transposed edge
    # stream — exactly how the paper runs back propagation through the same
    # accelerator (Section 2.2).
    dx = _aggregate_impl(g, dst, src, val, x.shape[0])
    from .edge_dot import edge_dot_impl

    dval = edge_dot_impl(x, g, src, dst).astype(val.dtype)
    f0 = lambda a: np.zeros(a.shape, dtype=jax.dtypes.float0)
    return dx, f0(src), f0(dst), dval


aggregate.defvjp(_aggregate_fwd, _aggregate_bwd)
