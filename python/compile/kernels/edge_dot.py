"""Per-edge feature dot products — VJP support kernel.

Computes ``out[e] = <x[src[e], :], g[dst[e], :]>`` for every edge.  This is
the gradient of the aggregate kernel w.r.t. the edge values and enables
user-defined layers (the paper's Scatter/Gather UDFs, Listing 2) with
*learnable* edge weights — something the fixed-normalization GCN/SAGE
layers never exercise but the framework abstraction allows.

Grid walks feature blocks; each grid step writes one row of a
``(num_feature_blocks, E)`` partial-dot matrix which the wrapper sums.
Keeping one output row per grid step (instead of accumulating into a shared
``(E,)`` buffer) avoids cross-grid-step output aliasing, which keeps the
kernel valid for both interpret mode and a real sequential-grid TPU
lowering.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import FEATURE_BLOCK, INTERPRET, ceil_to, pad_axis


def _edge_dot_kernel(src_ref, dst_ref, x_ref, g_ref, o_ref):
    # Vectorized per-edge gather-and-dot over the feature block (the
    # per-edge dynamic-slice loop was ~2 orders slower through interpret
    # mode — EXPERIMENTS.md §Perf).
    xs = x_ref[...][src_ref[...]]
    gs = g_ref[...][dst_ref[...]]
    o_ref[...] = jnp.sum(xs * gs, axis=1)[None, :].astype(o_ref.dtype)


def edge_dot_impl(x, g, src, dst):
    """Raw wrapper; see :func:`edge_dot`."""
    feat = x.shape[1]
    assert g.shape[1] == feat, f"feature dims disagree: {x.shape} vs {g.shape}"
    f_pad = ceil_to(feat, FEATURE_BLOCK)
    xp = pad_axis(x, 1, f_pad)
    gp = pad_axis(g.astype(x.dtype), 1, f_pad)
    nblocks = f_pad // FEATURE_BLOCK
    num_edges = src.shape[0]

    partial_dots = pl.pallas_call(
        _edge_dot_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(src.shape, lambda j: (0,)),
            pl.BlockSpec(dst.shape, lambda j: (0,)),
            pl.BlockSpec((x.shape[0], FEATURE_BLOCK), lambda j: (0, j)),
            pl.BlockSpec((g.shape[0], FEATURE_BLOCK), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, num_edges), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, num_edges), x.dtype),
        interpret=INTERPRET,
    )(src, dst, xp, gp)
    return jnp.sum(partial_dots, axis=0)


def edge_dot(x, g, src, dst):
    """``out[e] = <x[src[e]], g[dst[e]]>`` for each edge ``e``.

    Args:
      x:   ``(num_in, f)`` source-side features.
      g:   ``(num_out, f)`` destination-side features (usually a cotangent).
      src: ``(E,)`` int32 indices into ``x``.
      dst: ``(E,)`` int32 indices into ``g``.

    Returns:
      ``(E,)`` per-edge dot products in ``x.dtype``.
    """
    return edge_dot_impl(x, g, src, dst)
