"""Update kernel — Pallas twin of the paper's Fig. 6 HLS template.

The FPGA update kernel is a systolic MAC array performing block matrix
multiplication ``h^l = sigma(a^l W^l + b^l)`` with the (small, heavily
reused) layer weight W^l pinned in the on-chip Weight Buffer and the
elementwise sigma fused behind each MAC column.

On TPU this is an MXU-tiled blocked matmul: the grid walks (M, N) output
tiles, each kernel invocation keeps the *whole* K-strip of W resident in
VMEM (Weight-Buffer analog — GNN hidden dims are a few hundred, so
``K x TILE_N`` floats fit comfortably), and the bias + activation are fused
into the same kernel, never materializing the pre-activation in HBM.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, TILE_M, TILE_N, ceil_to, pad_axis

_ACTIVATIONS = ("none", "relu")


def _update_kernel(a_ref, w_ref, b_ref, o_ref, *, activation: str):
    acc = jnp.dot(
        a_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    acc = acc + b_ref[...].astype(jnp.float32)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _update_impl(a, w, b, activation: str):
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}; want one of {_ACTIVATIONS}")
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, f"inner dims disagree: {k} vs {k2}"
    mp, np_ = ceil_to(m, TILE_M), ceil_to(n, TILE_N)
    ap = pad_axis(a, 0, mp)
    wp = pad_axis(w, 1, np_)
    bp = pad_axis(b.reshape(1, -1), 1, np_)
    grid = (mp // TILE_M, np_ // TILE_N)

    out = pl.pallas_call(
        partial(_update_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, TILE_N), lambda i, j: (0, j)),
            pl.BlockSpec((1, TILE_N), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=INTERPRET,
    )(ap, wp, bp)
    return out[:m, :n]


def matmul(a, w):
    """Plain blocked matmul through the update kernel (no bias, no sigma).

    Used by the backward pass (dA = g W^T, dW = a^T g) so that backprop runs
    on the same hardware template as the forward pass.
    """
    zero_b = jnp.zeros((w.shape[1],), dtype=a.dtype)
    return _update_impl(a, w, zero_b, "none")


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def update(a, w, b, activation: str = "relu"):
    """Differentiable fused feature update ``sigma(a @ w + b)``.

    Args:
      a: ``(M, K)`` aggregated features a^l.
      w: ``(K, N)`` layer weight W^l (kept on-chip by the kernel).
      b: ``(N,)`` bias b^l.
      activation: ``"relu"`` or ``"none"`` (static).

    Returns:
      ``(M, N)`` updated features h^l.
    """
    return _update_impl(a, w, b, activation)


def _update_fwd(a, w, b, activation: str):
    pre = _update_impl(a, w, b, "none")
    out = jnp.maximum(pre, 0.0) if activation == "relu" else pre
    # Residual keeps the cheap relu mask, not the pre-activation matrix.
    mask = (pre > 0).astype(a.dtype) if activation == "relu" else None
    return out, (a, w, mask)


def _update_bwd(activation: str, res, g):
    a, w, mask = res
    g = g.astype(a.dtype)
    if mask is not None:
        g = g * mask
    da = matmul(g, w.T)
    dw = matmul(a.T, g)
    db = jnp.sum(g, axis=0)
    return da, dw.astype(w.dtype), db.astype(a.dtype)


update.defvjp(_update_fwd, _update_bwd)
