"""Layer-1 Pallas kernels for HP-GNN.

These kernels are the functional twin of the paper's two HLS hardware
templates (Section 4.2):

- :mod:`.aggregate` — the Aggregate kernel (Fig. 5): scatter-gather weighted
  neighbor aggregation over a COO edge stream that the L3 layout engine has
  prepared with the paper's RMT (sort-by-source) + RRA (vertex renaming)
  optimizations.
- :mod:`.update` — the Update kernel (Fig. 6): systolic-array block matmul
  with the layer weight pinned on-chip, fused bias + activation.
- :mod:`.edge_dot` — per-edge feature dot products, used for the VJP of the
  aggregate kernel w.r.t. edge values (supports user-defined layers with
  learnable edge weights).

Every kernel is lowered with ``interpret=True`` so the emitted HLO runs on
the CPU PJRT client that the rust runtime drives.  Real-TPU viability (VMEM
footprint, MXU utilization) is estimated structurally in DESIGN.md §Perf.

The public entry points (:func:`aggregate`, :func:`update`) carry custom
VJPs that route the backward pass through the same Pallas kernels, mirroring
the paper's observation that back propagation "performs a similar computation
as forward propagation but in the reverse direction".
"""

from .aggregate import aggregate, aggregate_fwd_only
from .update import update, matmul
from .edge_dot import edge_dot

__all__ = [
    "aggregate",
    "aggregate_fwd_only",
    "update",
    "matmul",
    "edge_dot",
]
