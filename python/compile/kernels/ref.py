"""Pure-jnp correctness oracles for the Pallas kernels.

Each function here is the mathematical definition of a kernel, written with
nothing but dense jnp ops (no pallas, no custom VJPs).  The pytest /
hypothesis suites assert ``assert_allclose(kernel, ref)`` over swept shapes
and dtypes; these references are also what the L2 model tests differentiate
through to validate the custom VJPs.
"""

import jax
import jax.numpy as jnp


def aggregate_ref(x, src, dst, val, num_out: int):
    """``out[v] = sum_{e: dst[e]==v} val[e] * x[src[e]]``."""
    contrib = x[src] * val.astype(x.dtype)[:, None]
    return jax.ops.segment_sum(contrib, dst, num_segments=num_out)


def update_ref(a, w, b, activation: str = "relu"):
    """``sigma(a @ w + b)`` in float32 accumulation."""
    out = (
        jnp.dot(a.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    )
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out.astype(a.dtype)


def edge_dot_ref(x, g, src, dst):
    """``out[e] = <x[src[e]], g[dst[e]]>``."""
    return jnp.sum(x[src] * g.astype(x.dtype)[dst], axis=1)


def gcn_layer_ref(x, src, dst, val, w, b, num_out: int, activation: str = "relu"):
    """Reference GCN layer: normalized aggregate then fused update (Eq. 1)."""
    agg = aggregate_ref(x, src, dst, val, num_out)
    return update_ref(agg, w, b, activation)


def sage_layer_ref(
    x, src, dst, val, self_idx, w, b, num_out: int, activation: str = "relu"
):
    """Reference GraphSAGE layer (Eq. 2): ``h_v || mean(neigh)`` then update.

    ``val`` carries the 1/(|N_s(v)|+1) mean coefficients (self loop included
    in the edge stream by the sampler); ``self_idx[v]`` is the row of v
    itself in ``x`` for the concat branch.
    """
    mean_agg = aggregate_ref(x, src, dst, val, num_out)
    self_feat = x[self_idx]
    cat = jnp.concatenate([self_feat, mean_agg], axis=1)
    return update_ref(cat, w, b, activation)
