"""Layer-2 JAX model: sampling-based mini-batch GNN training step.

Implements the paper's Algorithm 2 compute path — forward propagation
(Algorithm 1 over the sampled mini-batch), masked softmax cross-entropy
loss, back propagation, and weight update — as a single jitted function per
(model, geometry).  Aggregate()/Update() route through the Layer-1 Pallas
kernels; jax.grad drives the backward pass through their custom VJPs, so
backprop reuses the same two hardware templates in reverse, exactly as the
paper schedules it on the accelerator.

Everything here is build-time Python: ``aot.py`` lowers these functions to
HLO text once, and the rust coordinator executes them via PJRT on every
training iteration.

Batch argument convention (flat, fixed order — mirrored in the artifact
manifest consumed by rust):

    x0, labels, mask,
    [src_l, dst_l, val_l  for l = 1..L],
    [self_idx_l           for l = 1..L]   (SAGE only),
    [W_l, b_l             for l = 1..L],
    lr                                     (train step only)

Shapes come from :mod:`.geometry`; padding edges have ``val == 0`` and
padding targets ``mask == 0``.
"""

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .geometry import Geometry
from .kernels import aggregate, update

MODELS = ("gcn", "sage")


def weight_shapes(model: str, geom: Geometry) -> List[Tuple[Tuple[int, int], Tuple[int]]]:
    """Per-layer ``(W shape, b shape)``; SAGE doubles fan-in for the concat."""
    if model not in MODELS:
        raise ValueError(f"unknown model {model!r}; want one of {MODELS}")
    shapes = []
    for l in range(geom.layers):
        fin, fout = geom.f[l], geom.f[l + 1]
        if model == "sage":
            fin *= 2
        shapes.append(((fin, fout), (fout,)))
    return shapes


def init_params(model: str, geom: Geometry, seed: int = 0) -> List[jnp.ndarray]:
    """Glorot-uniform weights, zero biases — flat [W1, b1, ..., WL, bL]."""
    key = jax.random.PRNGKey(seed)
    params: List[jnp.ndarray] = []
    for (wshape, bshape) in weight_shapes(model, geom):
        key, sub = jax.random.split(key)
        limit = (6.0 / (wshape[0] + wshape[1])) ** 0.5
        params.append(jax.random.uniform(sub, wshape, jnp.float32, -limit, limit))
        params.append(jnp.zeros(bshape, jnp.float32))
    return params


def _layer(model: str, h, src, dst, val, self_idx, w, b, num_out: int, act: str):
    """One GNN layer (Algorithm 1 body) on top of the L1 kernels."""
    a = aggregate(h, src, dst, val, num_out)
    if model == "sage":
        # Eq. 2: h_v || mean(neigh ∪ self); the mean lives in `val`, the
        # concat branch gathers v's own row from the previous layer.
        a = jnp.concatenate([h[self_idx], a], axis=1)
    return update(a, w, b, act)


def forward(
    model: str,
    geom: Geometry,
    x0,
    edges: Sequence[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]],
    self_idx: Sequence[jnp.ndarray],
    params: Sequence[jnp.ndarray],
):
    """Mini-batch forward propagation; returns target-vertex logits."""
    h = x0
    ll = geom.layers
    for l in range(ll):
        src, dst, val = edges[l]
        act = "relu" if l < ll - 1 else "none"
        si = self_idx[l] if model == "sage" else None
        w, b = params[2 * l], params[2 * l + 1]
        h = _layer(model, h, src, dst, val, si, w, b, geom.b[l + 1], act)
    return h


def masked_xent(logits, labels, mask):
    """Mean softmax cross-entropy over unmasked (real) target vertices."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    mask = mask.astype(jnp.float32)
    return -jnp.sum(picked * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _unpack(model: str, geom: Geometry, args: Sequence[jnp.ndarray], with_lr: bool):
    """Split the flat argument list back into named groups."""
    ll = geom.layers
    it = iter(args)
    x0 = next(it)
    labels = next(it)
    mask = next(it)
    edges = [(next(it), next(it), next(it)) for _ in range(ll)]
    self_idx = [next(it) for _ in range(ll)] if model == "sage" else [None] * ll
    params = [next(it) for _ in range(2 * ll)]
    lr = next(it) if with_lr else None
    rest = list(it)
    assert not rest, f"{len(rest)} unconsumed args"
    return x0, labels, mask, edges, self_idx, params, lr


def make_forward_fn(model: str, geom: Geometry):
    """Flat-arg forward function for AOT export (inference / eval)."""

    def fn(*args):
        x0, _labels, _mask, edges, self_idx, params, _ = _unpack(
            model, geom, args, with_lr=False
        )
        return (forward(model, geom, x0, edges, self_idx, params),)

    return fn


def make_loss_fn(model: str, geom: Geometry):
    """Flat-arg (loss, logits) function — used for tests and eval export."""

    def fn(*args):
        x0, labels, mask, edges, self_idx, params, _ = _unpack(
            model, geom, args, with_lr=False
        )
        logits = forward(model, geom, x0, edges, self_idx, params)
        return masked_xent(logits, labels, mask), logits

    return fn


def make_train_step_fn(model: str, geom: Geometry):
    """Flat-arg SGD train step: returns ``(loss, new_W1, new_b1, ...)``.

    The learning rate is a scalar input so the rust coordinator can run
    schedules without recompiling; weights are threaded through the
    executable and live in rust between iterations (the FPGA-local-memory
    analog of keeping W^l resident).
    """

    def fn(*args):
        x0, labels, mask, edges, self_idx, params, lr = _unpack(
            model, geom, args, with_lr=True
        )

        def loss_of(params):
            logits = forward(model, geom, x0, edges, self_idx, params)
            return masked_xent(logits, labels, mask)

        loss, grads = jax.value_and_grad(loss_of)(list(params))
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return tuple([loss] + new_params)

    return fn


def make_adam_train_step_fn(model: str, geom: Geometry, b1=0.9, b2=0.999, eps=1e-8):
    """Adam variant: extra flat inputs ``[m_i, v_i ...], step`` after lr.

    Returns ``(loss, new_params..., new_m..., new_v..., new_step)``.
    """

    def fn(*args):
        ll = geom.layers
        nparams = 2 * ll
        nstate = nparams
        base, tail = args[: len(args) - 2 * nstate - 1], args[len(args) - 2 * nstate - 1 :]
        m_state = list(tail[:nstate])
        v_state = list(tail[nstate : 2 * nstate])
        step = tail[-1]
        x0, labels, mask, edges, self_idx, params, lr = _unpack(
            model, geom, base, with_lr=True
        )

        def loss_of(params):
            logits = forward(model, geom, x0, edges, self_idx, params)
            return masked_xent(logits, labels, mask)

        loss, grads = jax.value_and_grad(loss_of)(list(params))
        t = step + 1.0
        outs_p, outs_m, outs_v = [], [], []
        for p, g, m, v in zip(params, grads, m_state, v_state):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1**t)
            vhat = v / (1 - b2**t)
            outs_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            outs_m.append(m)
            outs_v.append(v)
        return tuple([loss] + outs_p + outs_m + outs_v + [t])

    return fn


def example_args(model: str, geom: Geometry, with_lr: bool, seed: int = 0):
    """ShapeDtypeStructs + names for lowering; order defines the ABI."""
    ll = geom.layers
    specs = []

    def add(name, shape, dtype):
        specs.append((name, jax.ShapeDtypeStruct(shape, dtype)))

    add("x0", (geom.b[0], geom.f[0]), jnp.float32)
    add("labels", (geom.b[ll],), jnp.int32)
    add("mask", (geom.b[ll],), jnp.float32)
    for l in range(1, ll + 1):
        add(f"src{l}", (geom.e[l - 1],), jnp.int32)
        add(f"dst{l}", (geom.e[l - 1],), jnp.int32)
        add(f"val{l}", (geom.e[l - 1],), jnp.float32)
    if model == "sage":
        for l in range(1, ll + 1):
            add(f"self_idx{l}", (geom.b[l],), jnp.int32)
    for l, (wshape, bshape) in enumerate(weight_shapes(model, geom), start=1):
        add(f"w{l}", wshape, jnp.float32)
        add(f"b{l}", bshape, jnp.float32)
    if with_lr:
        add("lr", (), jnp.float32)
    return specs
