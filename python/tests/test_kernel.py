"""Kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes and dtypes of the Pallas kernels and asserts
allclose against ref.py.  Deadlines are disabled: interpret-mode pallas
goes through XLA compilation on first touch of each shape.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate, aggregate_fwd_only, edge_dot, matmul, update
from compile.kernels import ref
from compile.kernels.common import FEATURE_BLOCK, ceil_to, pad_axis

SETTINGS = dict(max_examples=12, deadline=None)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


def _rand_graph(rng, num_in, num_out, num_edges, feat, dtype):
    x = jnp.asarray(rng.normal(size=(num_in, feat)).astype(np.float32)).astype(dtype)
    src = jnp.asarray(rng.integers(0, num_in, num_edges).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, num_out, num_edges).astype(np.int32))
    val = jnp.asarray(rng.normal(size=num_edges).astype(np.float32)).astype(dtype)
    return x, src, dst, val


class TestAggregate:
    @settings(**SETTINGS)
    @given(
        num_in=st.integers(1, 70),
        num_out=st.integers(1, 40),
        num_edges=st.integers(1, 200),
        feat=st.integers(1, 300),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_f32(self, num_in, num_out, num_edges, feat, seed):
        rng = np.random.default_rng(seed)
        x, src, dst, val = _rand_graph(rng, num_in, num_out, num_edges, feat, jnp.float32)
        got = aggregate(x, src, dst, val, num_out)
        want = ref.aggregate_ref(x, src, dst, val, num_out)
        assert got.shape == (num_out, feat)
        np.testing.assert_allclose(got, want, **_tol(jnp.float32))

    @settings(**SETTINGS)
    @given(
        num_in=st.integers(1, 40),
        num_out=st.integers(1, 20),
        num_edges=st.integers(1, 80),
        feat=st.integers(1, 160),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_bf16(self, num_in, num_out, num_edges, feat, seed):
        rng = np.random.default_rng(seed)
        x, src, dst, val = _rand_graph(rng, num_in, num_out, num_edges, feat, jnp.bfloat16)
        got = aggregate(x, src, dst, val, num_out).astype(jnp.float32)
        want = ref.aggregate_ref(x, src, dst, val, num_out).astype(jnp.float32)
        np.testing.assert_allclose(got, want, **_tol(jnp.bfloat16))

    def test_zero_valued_padding_edges_are_noops(self):
        rng = np.random.default_rng(1)
        x, src, dst, val = _rand_graph(rng, 10, 6, 20, 33, jnp.float32)
        base = aggregate(x, src, dst, val, 6)
        # Append pure-padding edges: val == 0 pointing anywhere valid.
        srcp = jnp.concatenate([src, jnp.zeros(7, jnp.int32)])
        dstp = jnp.concatenate([dst, jnp.full((7,), 5, jnp.int32)])
        valp = jnp.concatenate([val, jnp.zeros(7, jnp.float32)])
        padded = aggregate(x, srcp, dstp, valp, 6)
        np.testing.assert_allclose(base, padded, rtol=1e-6, atol=1e-6)

    def test_isolated_destination_stays_zero(self):
        rng = np.random.default_rng(2)
        x, src, _dst, val = _rand_graph(rng, 8, 5, 12, 16, jnp.float32)
        dst = jnp.asarray(rng.integers(0, 4, 12).astype(np.int32))  # never 4
        out = aggregate(x, src, dst, val, 5)
        np.testing.assert_allclose(out[4], np.zeros(16), atol=0)

    def test_fwd_only_matches_vjp_version(self):
        rng = np.random.default_rng(3)
        x, src, dst, val = _rand_graph(rng, 11, 9, 31, 45, jnp.float32)
        np.testing.assert_allclose(
            aggregate_fwd_only(x, src, dst, val, 9),
            aggregate(x, src, dst, val, 9),
            rtol=0,
            atol=0,
        )

    def test_duplicate_edges_accumulate(self):
        x = jnp.ones((2, 4), jnp.float32)
        src = jnp.zeros(3, jnp.int32)
        dst = jnp.zeros(3, jnp.int32)
        val = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
        out = aggregate(x, src, dst, val, 1)
        np.testing.assert_allclose(out, np.full((1, 4), 6.0), rtol=1e-6)

    def test_jit_compatible(self):
        rng = np.random.default_rng(4)
        x, src, dst, val = _rand_graph(rng, 10, 5, 15, 20, jnp.float32)
        f = jax.jit(lambda *a: aggregate(*a, 5))
        np.testing.assert_allclose(
            f(x, src, dst, val), ref.aggregate_ref(x, src, dst, val, 5), rtol=1e-5, atol=1e-5
        )


class TestUpdate:
    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 130),
        n=st.integers(1, 150),
        act=st.sampled_from(["relu", "none"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_f32(self, m, k, n, act, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=n).astype(np.float32))
        got = update(a, w, b, act)
        want = ref.update_ref(a, w, b, act)
        assert got.shape == (m, n)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 64),
        k=st.integers(1, 64),
        n=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_bf16(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(jnp.bfloat16)
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(jnp.bfloat16)
        b = jnp.asarray(rng.normal(size=n).astype(np.float32)).astype(jnp.bfloat16)
        got = update(a, w, b, "relu").astype(jnp.float32)
        want = ref.update_ref(a, w, b, "relu").astype(jnp.float32)
        np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)

    def test_relu_clamps(self):
        a = jnp.asarray([[-1.0, 2.0]], jnp.float32)
        w = jnp.eye(2, dtype=jnp.float32)
        b = jnp.zeros(2, jnp.float32)
        np.testing.assert_allclose(update(a, w, b, "relu"), [[0.0, 2.0]])
        np.testing.assert_allclose(update(a, w, b, "none"), [[-1.0, 2.0]])

    def test_bad_activation_raises(self):
        a = jnp.ones((2, 2), jnp.float32)
        with pytest.raises(ValueError, match="activation"):
            update(a, a, jnp.zeros(2), "gelu")

    def test_matmul_helper(self):
        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.normal(size=(33, 17)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(17, 29)).astype(np.float32))
        np.testing.assert_allclose(matmul(a, w), a @ w, rtol=1e-4, atol=1e-4)


class TestEdgeDot:
    @settings(**SETTINGS)
    @given(
        num_in=st.integers(1, 50),
        num_out=st.integers(1, 30),
        num_edges=st.integers(1, 120),
        feat=st.integers(1, 260),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, num_in, num_out, num_edges, feat, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(num_in, feat)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(num_out, feat)).astype(np.float32))
        src = jnp.asarray(rng.integers(0, num_in, num_edges).astype(np.int32))
        dst = jnp.asarray(rng.integers(0, num_out, num_edges).astype(np.int32))
        got = edge_dot(x, g, src, dst)
        want = ref.edge_dot_ref(x, g, src, dst)
        assert got.shape == (num_edges,)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_multi_feature_block_sums_partials(self):
        # feat > FEATURE_BLOCK exercises the partial-dot reduction.
        feat = FEATURE_BLOCK * 2 + 13
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.normal(size=(4, feat)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(3, feat)).astype(np.float32))
        src = jnp.asarray([0, 1, 2, 3], np.int32)
        dst = jnp.asarray([0, 1, 2, 0], np.int32)
        np.testing.assert_allclose(
            edge_dot(x, g, src, dst), ref.edge_dot_ref(x, g, src, dst), rtol=1e-3, atol=1e-3
        )


class TestCommonHelpers:
    @settings(**SETTINGS)
    @given(x=st.integers(-5, 2000), m=st.sampled_from([8, 128, 512]))
    def test_ceil_to(self, x, m):
        out = ceil_to(x, m)
        assert out % m == 0 and out >= max(x, 1)
        assert out - m < max(x, m)

    def test_pad_axis_rejects_shrink(self):
        with pytest.raises(ValueError):
            pad_axis(jnp.ones((4, 4)), 0, 2)

    def test_pad_axis_value(self):
        out = pad_axis(jnp.ones((2, 2)), 1, 4, value=7)
        np.testing.assert_allclose(out[:, 2:], np.full((2, 2), 7.0))
