"""AOT pipeline tests.

The HLO-text artifact's *numeric* round-trip (text -> HloModuleProto ->
PJRT compile -> execute) is owned by the rust runtime integration tests
(`rust/tests/runtime_roundtrip.rs`) — rust is the only runtime consumer.
Here we validate the python half of the contract:

* the emitted text parses back into an HloModule (catches emission bugs),
* the entry computation's parameter count matches the manifest ABI,
* manifest metadata is coherent with the geometry registry, and
* the *function being exported* computes what the jitted model computes
  (same tracer, so this pins the lowering input).
"""

import json
import os

import jax
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, geometry, model
from tests.test_model import _flat, _rand_batch

TINY = geometry.get("tiny")


@pytest.fixture(scope="module")
def tiny_exports(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entries = {}
    for mdl in model.MODELS:
        for kind in ("train_step", "forward"):
            entries[(mdl, kind)] = aot.export_one(mdl, "tiny", kind, str(out))
    return out, entries


def test_manifest_records_io(tiny_exports):
    _out, entries = tiny_exports
    e = entries[("gcn", "train_step")]
    names = [i["name"] for i in e["inputs"]]
    assert names[:3] == ["x0", "labels", "mask"]
    assert names[-1] == "lr"
    assert e["outputs"][0] == "loss"
    assert e["geometry_spec"]["b"] == list(TINY.b)
    f = entries[("gcn", "forward")]
    assert "lr" not in [i["name"] for i in f["inputs"]]
    assert f["outputs"] == ["logits"]


@pytest.mark.parametrize("mdl", model.MODELS)
@pytest.mark.parametrize("kind", ["train_step", "forward"])
def test_hlo_text_parses_and_matches_abi(tiny_exports, mdl, kind):
    out, entries = tiny_exports
    entry = entries[(mdl, kind)]
    with open(os.path.join(out, entry["file"])) as f:
        text = f.read()
    xc._xla.hlo_module_from_text(text)  # raises on malformed text
    # ENTRY signature: one parameter per manifest input.  Parameters in
    # nested computations (while bodies, fusions) don't count, so scan only
    # the ENTRY block.
    start = text.index("ENTRY ")
    depth = 0
    end = start
    for i, ch in enumerate(text[start:], start):
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                end = i
                break
    entry_block = text[start:end]
    import re

    params = set(re.findall(r"= [^=]*parameter\((\d+)\)", entry_block))
    assert len(params) == len(entry["inputs"])


@pytest.mark.parametrize("mdl", model.MODELS)
def test_exported_fn_equals_jitted_model(mdl):
    """The function handed to jax.jit(...).lower is the model's train step."""
    args, edges, self_idx, params = _rand_batch(TINY, mdl, seed=11, real_targets=4)
    flat = _flat(args, edges, self_idx, params, mdl, lr=0.05)
    fn = model.make_train_step_fn(mdl, TINY)
    eager = fn(*flat)
    jitted = jax.jit(fn)(*flat)
    for e, j in zip(eager, jitted):
        np.testing.assert_allclose(np.asarray(e), np.asarray(j), rtol=1e-5, atol=1e-6)
    # Loss improves over a couple of eager steps (sanity of exported fn).
    p = list(eager[1:])
    out2 = fn(*_flat(args, edges, self_idx, p, mdl, lr=0.05))
    assert float(out2[0]) <= float(eager[0]) + 1e-3


def test_repo_manifest_consistent_when_present():
    """If `make artifacts` has run, the checked manifest must be coherent."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts/ not built")
    with open(path) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    for e in manifest["artifacts"]:
        hlo = os.path.join(os.path.dirname(path), e["file"])
        assert os.path.exists(hlo), f"missing {e['file']}"
        g = geometry.get(e["geometry"])
        assert e["geometry_spec"]["b"] == list(g.b)
        with_lr = e["kind"] in ("train_step", "adam_step")
        want_names = [n for n, _ in model.example_args(e["model"], g, with_lr=with_lr)]
        if e["kind"] == "adam_step":
            # Adam state trails the base ABI (see aot.py).
            ll = g.layers
            for l in range(1, ll + 1):
                want_names += [f"m_w{l}", f"m_b{l}"]
            for l in range(1, ll + 1):
                want_names += [f"v_w{l}", f"v_b{l}"]
            want_names.append("step")
        assert [i["name"] for i in e["inputs"]] == want_names, e["name"]
