"""Custom-VJP correctness: gradients through the Pallas kernels must match
gradients through the pure-jnp reference composition."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate, update
from compile.kernels import ref

SETTINGS = dict(max_examples=8, deadline=None)


def _setup(seed, num_in=11, num_out=7, num_edges=23, feat=19, fout=9):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(num_in, feat)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, num_in, num_edges).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, num_out, num_edges).astype(np.int32))
    val = jnp.asarray(rng.normal(size=num_edges).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(feat, fout)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=fout).astype(np.float32))
    ct = jnp.asarray(rng.normal(size=(num_out, fout)).astype(np.float32))
    return x, src, dst, val, w, b, ct, num_out


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_layer_grads_match_ref(seed):
    x, src, dst, val, w, b, ct, num_out = _setup(seed)

    def loss_k(x, val, w, b):
        h = update(aggregate(x, src, dst, val, num_out), w, b, "relu")
        return jnp.sum(h * ct)

    def loss_r(x, val, w, b):
        h = ref.update_ref(ref.aggregate_ref(x, src, dst, val, num_out), w, b, "relu")
        return jnp.sum(h * ct)

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(x, val, w, b)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(x, val, w, b)
    for a, b_, name in zip(gk, gr, ("x", "val", "w", "b")):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-3, err_msg=f"grad {name}")


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_aggregate_grad_is_transposed_aggregate(seed):
    """dL/dx of sum(val_e * x[src_e]) routed to dst is aggregation on the
    reversed edge stream — the paper's reverse-direction backprop."""
    x, src, dst, val, _, _, _, num_out = _setup(seed)
    g = jnp.asarray(
        np.random.default_rng(seed + 1).normal(size=(num_out, x.shape[1])).astype(np.float32)
    )
    dx = jax.grad(lambda x: jnp.sum(aggregate(x, src, dst, val, num_out) * g))(x)
    want = ref.aggregate_ref(g, dst, src, val, x.shape[0])
    np.testing.assert_allclose(dx, want, rtol=1e-3, atol=1e-3)


def test_update_relu_mask_grad():
    a = jnp.asarray([[1.0, -2.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2, jnp.float32)
    da = jax.grad(lambda a: jnp.sum(update(a, w, b, "relu")))(a)
    # Second column is clamped by relu -> zero gradient flows back.
    np.testing.assert_allclose(da, [[1.0, 0.0]])


def test_grad_under_jit_and_value_and_grad():
    x, src, dst, val, w, b, ct, num_out = _setup(42)

    @jax.jit
    def step(x, w, b):
        def loss(w, b):
            h = update(aggregate(x, src, dst, val, num_out), w, b, "relu")
            return jnp.sum(h * ct)

        return jax.value_and_grad(loss, argnums=(0, 1))(w, b)

    loss_v, (dw, db) = step(x, w, b)
    assert np.isfinite(float(loss_v))
    assert dw.shape == w.shape and db.shape == b.shape


def test_second_application_consistent():
    """Two backward passes over the same primal give identical results
    (kernels are deterministic — matters for the RAW-hazard analog)."""
    x, src, dst, val, w, b, ct, num_out = _setup(7)
    f = jax.grad(lambda x: jnp.sum(aggregate(x, src, dst, val, num_out) ** 2))
    np.testing.assert_array_equal(f(x), f(x))
