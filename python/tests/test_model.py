"""L2 model tests: layer semantics vs reference, masked loss, train step
convergence, Adam state threading, ABI (example_args) consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import geometry, model
from compile.kernels import ref

TINY = geometry.get("tiny")


def _rand_batch(geom, mdl, seed=0, real_targets=None):
    """Random (valid) padded mini-batch honoring the geometry contract."""
    rng = np.random.default_rng(seed)
    ll = geom.layers
    args = {}
    args["x0"] = jnp.asarray(rng.normal(size=(geom.b[0], geom.f[0])).astype(np.float32))
    nt = geom.b[ll] if real_targets is None else real_targets
    labels = rng.integers(0, geom.num_classes, geom.b[ll]).astype(np.int32)
    mask = np.zeros(geom.b[ll], np.float32)
    mask[:nt] = 1.0
    args["labels"] = jnp.asarray(labels)
    args["mask"] = jnp.asarray(mask)
    edges = []
    for l in range(1, ll + 1):
        e = geom.e[l - 1]
        src = rng.integers(0, geom.b[l - 1], e).astype(np.int32)
        dst = rng.integers(0, geom.b[l], e).astype(np.int32)
        val = rng.normal(size=e).astype(np.float32)
        edges.append((jnp.asarray(src), jnp.asarray(dst), jnp.asarray(val)))
    self_idx = [
        jnp.asarray(rng.integers(0, geom.b[l - 1], geom.b[l]).astype(np.int32))
        for l in range(1, ll + 1)
    ]
    params = model.init_params(mdl, geom, seed=seed)
    return args, edges, self_idx, params


def _flat(args, edges, self_idx, params, mdl, lr=None):
    flat = [args["x0"], args["labels"], args["mask"]]
    for (s, d, v) in edges:
        flat += [s, d, v]
    if mdl == "sage":
        flat += list(self_idx)
    flat += list(params)
    if lr is not None:
        flat.append(jnp.asarray(lr, jnp.float32))
    return flat


class TestForward:
    @pytest.mark.parametrize("mdl", model.MODELS)
    def test_forward_matches_ref_layers(self, mdl):
        args, edges, self_idx, params = _rand_batch(TINY, mdl, seed=1)
        got = model.forward(mdl, TINY, args["x0"], edges, self_idx, params)

        h = args["x0"]
        ll = TINY.layers
        for l in range(ll):
            src, dst, val = edges[l]
            act = "relu" if l < ll - 1 else "none"
            w, b = params[2 * l], params[2 * l + 1]
            if mdl == "gcn":
                h = ref.gcn_layer_ref(h, src, dst, val, w, b, TINY.b[l + 1], act)
            else:
                h = ref.sage_layer_ref(
                    h, src, dst, val, self_idx[l], w, b, TINY.b[l + 1], act
                )
        np.testing.assert_allclose(got, h, rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("mdl", model.MODELS)
    def test_forward_fn_flat_abi(self, mdl):
        args, edges, self_idx, params = _rand_batch(TINY, mdl, seed=2)
        fn = model.make_forward_fn(mdl, TINY)
        (logits,) = fn(*_flat(args, edges, self_idx, params, mdl))
        direct = model.forward(mdl, TINY, args["x0"], edges, self_idx, params)
        np.testing.assert_array_equal(logits, direct)
        assert logits.shape == (TINY.b[-1], TINY.num_classes)


class TestLoss:
    def test_masked_xent_ignores_padding(self):
        logits = jnp.asarray(np.random.default_rng(0).normal(size=(6, 4)), jnp.float32)
        labels = jnp.asarray([0, 1, 2, 3, 0, 1], jnp.int32)
        mask_all = jnp.ones(6, jnp.float32)
        mask_half = jnp.asarray([1, 1, 1, 0, 0, 0], jnp.float32)
        full = model.masked_xent(logits, labels, mask_all)
        # Corrupt the masked rows: loss over the unmasked prefix must not move.
        corrupted = logits.at[3:].set(1e3)
        half = model.masked_xent(corrupted, labels, mask_half)
        want = model.masked_xent(logits[:3], labels[:3], jnp.ones(3, jnp.float32))
        np.testing.assert_allclose(half, want, rtol=1e-6)
        assert not np.allclose(full, half)

    def test_all_masked_is_finite(self):
        logits = jnp.ones((4, 3), jnp.float32)
        labels = jnp.zeros(4, jnp.int32)
        loss = model.masked_xent(logits, labels, jnp.zeros(4, jnp.float32))
        assert float(loss) == 0.0


class TestTrainStep:
    @pytest.mark.parametrize("mdl", model.MODELS)
    def test_loss_decreases(self, mdl):
        args, edges, self_idx, params = _rand_batch(TINY, mdl, seed=3, real_targets=4)
        step = jax.jit(model.make_train_step_fn(mdl, TINY))
        losses = []
        for _ in range(30):
            out = step(*_flat(args, edges, self_idx, params, mdl, lr=0.05))
            losses.append(float(out[0]))
            params = list(out[1:])
        assert losses[-1] < losses[0] * 0.8, losses

    def test_zero_lr_keeps_weights(self):
        args, edges, self_idx, params = _rand_batch(TINY, "gcn", seed=4)
        step = model.make_train_step_fn("gcn", TINY)
        out = step(*_flat(args, edges, self_idx, params, "gcn", lr=0.0))
        for p, q in zip(params, out[1:]):
            np.testing.assert_array_equal(p, q)

    def test_adam_state_threading(self):
        args, edges, self_idx, params = _rand_batch(TINY, "gcn", seed=5, real_targets=4)
        step = jax.jit(model.make_adam_train_step_fn("gcn", TINY))
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        t = jnp.asarray(0.0, jnp.float32)
        n = len(params)
        losses = []
        for i in range(25):
            out = step(*_flat(args, edges, self_idx, params, "gcn", lr=0.01), *m, *v, t)
            losses.append(float(out[0]))
            params = list(out[1 : 1 + n])
            m = list(out[1 + n : 1 + 2 * n])
            v = list(out[1 + 2 * n : 1 + 3 * n])
            t = out[-1]
        assert float(t) == 25.0
        assert losses[-1] < losses[0]


class TestABI:
    @pytest.mark.parametrize("mdl", model.MODELS)
    @pytest.mark.parametrize("with_lr", [True, False])
    def test_example_args_cover_signature(self, mdl, with_lr):
        specs = model.example_args(mdl, TINY, with_lr=with_lr)
        names = [n for n, _ in specs]
        assert names[0:3] == ["x0", "labels", "mask"]
        assert len(names) == len(set(names)), "duplicate arg names"
        fn = (
            model.make_train_step_fn(mdl, TINY)
            if with_lr
            else model.make_forward_fn(mdl, TINY)
        )
        # Must trace cleanly with exactly these specs.
        jax.eval_shape(fn, *[s for _, s in specs])

    def test_weight_shapes_sage_doubles_fanin(self):
        gcn = model.weight_shapes("gcn", TINY)
        sage = model.weight_shapes("sage", TINY)
        for (gw, _), (sw, _) in zip(gcn, sage):
            assert sw[0] == 2 * gw[0] and sw[1] == gw[1]

    def test_unknown_model_rejected(self):
        with pytest.raises(ValueError, match="unknown model"):
            model.weight_shapes("gat", TINY)


class TestGeometry:
    def test_registry_entries_valid(self):
        for name in geometry.GEOMETRIES:
            g = geometry.get(name)
            assert g.layers >= 1 and g.total_vertices == sum(g.b)

    def test_monotone_b_enforced(self):
        with pytest.raises(ValueError, match="exceeds"):
            geometry.Geometry("bad", b=(4, 16, 4), e=(8, 8), f=(4, 4, 4))

    def test_unknown_geometry(self):
        with pytest.raises(KeyError, match="unknown geometry"):
            geometry.get("nope")
